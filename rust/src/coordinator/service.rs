//! The solve services: register matrices once, serve streams of RHS
//! requests.
//!
//! The serving runtime is **sharded and multi-matrix**
//! ([`ShardedSolveService`]): N matrices are registered by key into a
//! [`MatrixRegistry`] (each compiled, simulated and planned exactly once,
//! then pinned to a shard round-robin), and every
//! [`SolveRequest`]` { matrix_key, b, reply }` is routed to the shard
//! that owns its matrix. Each shard drains its own mpsc queue with a
//! small worker pool, batching same-matrix requests through the
//! backend's multi-RHS path; responses return through per-request
//! channels. Per-shard [`ShardCounters`] aggregate into service-wide
//! [`ServingStats`].
//!
//! The numeric path is a pluggable [`SolverBackend`] chosen at startup by
//! [`create_backend`] and — by default — **shared across every shard and
//! matrix**, so the native backend's persistent MGD worker pool is
//! spawned once per service (or once per backend lifetime, when an
//! embedder reuses a backend across service restarts) rather than per
//! solve or per matrix. Registration calls
//! [`SolverBackend::prepare`], so plan construction and pool spawn happen
//! at register time, not on the first request.
//!
//! Matrices are **dynamic**, not pinned forever:
//! [`ShardedSolveService::evict`] retires a key after draining its
//! in-flight requests (every routed request carries a drop-guarded
//! in-flight mark, so the drain cannot be wedged or racily skipped), and
//! [`ShardedSolveService::swap`] replaces a key's matrix live — the new
//! entry is compiled/planned/warmed off the hot path and published in one
//! atomic pointer move while requests keep flowing.
//!
//! Failures are loud, never hangs: backend construction errors fail
//! `start`, registration (compile/verify) errors fail `register`, an
//! unknown `matrix_key` gets an immediate error *reply*, and per-request
//! solver errors are replied to the requester — workers never exit
//! silently with requests pending.
//!
//! [`SolveService`] remains as the single-matrix facade (CLI `mgd solve`,
//! benches): a 1-shard service with one matrix registered under an
//! internal key.

use super::metrics::{ServingStats, ShardCounters, ShardStats, SolveMetrics};
use super::registry::{MatrixRegistry, RegisteredMatrix};
use crate::compiler::{CompilerConfig, Program};
use crate::matrix::CsrMatrix;
use crate::runtime::{create_backend, BackendConfig, SolverBackend};
use anyhow::{anyhow, Context, Result};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Configuration of the sharded multi-matrix service.
#[derive(Debug, Clone)]
pub struct ShardedServiceConfig {
    /// Compiler/architecture options used at registration.
    pub compiler: CompilerConfig,
    /// Number of shards (request queues); matrices are assigned to shards
    /// round-robin at registration. Clamped to ≥ 1.
    pub shards: usize,
    /// Worker threads draining each shard's queue.
    pub workers_per_shard: usize,
    /// Max requests drained per dispatch round of one shard worker.
    pub batch_size: usize,
    /// Numeric backend selection (native by default).
    pub backend: BackendConfig,
    /// When true, every shard constructs its own backend instance (own
    /// worker pools — more threads, shard-parallel numerics). The default
    /// `false` shares one backend, and therefore one persistent MGD pool,
    /// across all shards: a solve already fans out across the pool's
    /// workers, so shards contend on cores either way and sharing keeps
    /// the thread count bounded.
    pub backend_per_shard: bool,
}

impl Default for ShardedServiceConfig {
    fn default() -> Self {
        Self {
            compiler: CompilerConfig::default(),
            shards: 2,
            workers_per_shard: 2,
            batch_size: 8,
            backend: BackendConfig::default(),
            backend_per_shard: false,
        }
    }
}

/// Single-matrix service configuration (the [`SolveService`] facade).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Compiler/architecture options.
    pub compiler: CompilerConfig,
    /// Worker threads serving the numeric path.
    pub workers: usize,
    /// Max requests drained per batch round.
    pub batch_size: usize,
    /// Numeric backend selection (native by default).
    pub backend: BackendConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            compiler: CompilerConfig::default(),
            workers: 2,
            batch_size: 8,
            backend: BackendConfig::default(),
        }
    }
}

/// One solve request of the sharded service: which matrix, which RHS,
/// and where to send the reply.
pub struct SolveRequest {
    /// Registration key of the matrix to solve against.
    pub matrix_key: String,
    /// Right-hand side (length = the matrix's order).
    pub b: Vec<f32>,
    /// Response channel.
    pub reply: mpsc::Sender<Result<SolveResponse>>,
}

/// One solve response.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    /// Solution vector.
    pub x: Vec<f32>,
    /// Host wall-clock latency of the numeric path (seconds, averaged
    /// over the dispatch batch the request rode in). May be 0.0 for tiny
    /// solves at coarse timer resolution.
    pub host_seconds: f64,
    /// Shared accelerator metrics for this matrix.
    pub metrics: SolveMetrics,
}

/// Owns one in-flight mark on a registry entry; checked out at route
/// time, checked back in when dropped. Dropping *after* the reply send
/// means [`ShardedSolveService::evict`] cannot return while any reply is
/// still owed — and because it is a drop guard, a job that dies on the
/// floor (worker panic, shutdown teardown) still checks in instead of
/// wedging a future evict forever.
struct InflightGuard(Arc<RegisteredMatrix>);

impl InflightGuard {
    /// The resolved registry entry this mark belongs to.
    fn entry(&self) -> &Arc<RegisteredMatrix> {
        &self.0
    }
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.note_done();
    }
}

/// A routed job on a shard queue: the registry entry is resolved at
/// submit time (owned by the in-flight guard) so shard workers never
/// touch the key map.
struct ShardJob {
    b: Vec<f32>,
    reply: mpsc::Sender<Result<SolveResponse>>,
    /// In-flight mark owning the resolved entry, dropped after the reply
    /// is delivered.
    guard: InflightGuard,
}

/// One shard: its queue, its workers, its counters, its backend handle.
struct Shard {
    tx: Option<mpsc::Sender<ShardJob>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    counters: Arc<ShardCounters>,
    backend: Arc<dyn SolverBackend>,
}

/// The running sharded multi-matrix service.
pub struct ShardedSolveService {
    registry: Arc<MatrixRegistry>,
    shards: Vec<Shard>,
    backend_name: &'static str,
}

impl ShardedSolveService {
    /// Construct the configured backend(s) ([`create_backend`] — failures
    /// are startup errors) and spawn the shard queues and worker pools.
    /// The service starts with an empty registry; add matrices with
    /// [`ShardedSolveService::register`].
    pub fn start(cfg: ShardedServiceConfig) -> Result<Self> {
        let nshards = cfg.shards.max(1);
        let shared = (!cfg.backend_per_shard)
            .then(|| create_backend(&cfg.backend))
            .transpose()
            .context("construct solver backend")?;
        let mut backends = Vec::with_capacity(nshards);
        for shard in 0..nshards {
            backends.push(match &shared {
                Some(b) => Arc::clone(b),
                None => create_backend(&cfg.backend)
                    .with_context(|| format!("construct solver backend for shard {shard}"))?,
            });
        }
        Ok(Self::start_shards(backends, &cfg))
    }

    /// Like [`ShardedSolveService::start`] but with one caller-provided
    /// backend shared by every shard (dependency injection for tests,
    /// benches and embedders — e.g. reusing one backend, and thereby one
    /// persistent worker pool, across repeated service start/shutdown
    /// cycles). `cfg.backend` and `cfg.backend_per_shard` are ignored.
    pub fn start_with_backend(backend: Arc<dyn SolverBackend>, cfg: ShardedServiceConfig) -> Self {
        let backends = (0..cfg.shards.max(1)).map(|_| Arc::clone(&backend)).collect();
        Self::start_shards(backends, &cfg)
    }

    fn start_shards(backends: Vec<Arc<dyn SolverBackend>>, cfg: &ShardedServiceConfig) -> Self {
        let backend_name = backends[0].name();
        let registry = Arc::new(MatrixRegistry::new(backends.len(), cfg.compiler.clone()));
        let batch = cfg.batch_size.max(1);
        let shards = backends
            .into_iter()
            .map(|backend| {
                let (tx, rx) = mpsc::channel::<ShardJob>();
                let rx = Arc::new(Mutex::new(rx));
                let counters = Arc::new(ShardCounters::default());
                let workers = (0..cfg.workers_per_shard.max(1))
                    .map(|_| {
                        let rx = Arc::clone(&rx);
                        let backend = Arc::clone(&backend);
                        let counters = Arc::clone(&counters);
                        std::thread::spawn(move || shard_worker(&rx, &*backend, &counters, batch))
                    })
                    .collect();
                Shard {
                    tx: Some(tx),
                    workers,
                    counters,
                    backend,
                }
            })
            .collect();
        Self {
            registry,
            shards,
            backend_name,
        }
    }

    /// Register `m` under `key`: compile + simulate + plan once (see
    /// [`MatrixRegistry::register`]), then warm the owning shard's
    /// backend ([`SolverBackend::prepare`] — for the native backend this
    /// builds the cached MGD plan and spawns the persistent pool). After
    /// this returns, requests for `key` pay zero setup.
    pub fn register(&self, key: &str, m: &CsrMatrix) -> Result<Arc<RegisteredMatrix>> {
        let entry = self.registry.register(key, m)?;
        if let Err(e) = self.shards[entry.shard()].backend.prepare(entry.solver()) {
            // Roll the registration back: a key must not stay routed to
            // a backend that failed to prepare (retries would otherwise
            // hit "already registered" forever).
            let _ = self.registry.remove(key);
            return Err(e.context(format!("prepare backend for matrix {key:?}")));
        }
        Ok(entry)
    }

    /// Evict the matrix registered under `key`: the key becomes unknown
    /// immediately (new submits get the error reply), the call blocks
    /// until every request already routed for the key has been replied
    /// to, and the drained entry is returned (its final `served` count is
    /// readable; dropping it releases the plan). The key is then free for
    /// re-registration. Errors if `key` is not registered.
    ///
    /// Call from a control-plane thread, not from inside a shard worker
    /// (a worker cannot drain its own queue while blocked here).
    pub fn evict(&self, key: &str) -> Result<Arc<RegisteredMatrix>> {
        self.registry
            .evict(key)
            .with_context(|| format!("evict: matrix key {key:?} is not registered"))
    }

    /// Replace the matrix registered under `key` **live**: compile,
    /// simulate and plan `m` off the hot path, warm the owning shard's
    /// backend ([`SolverBackend::prepare`]), then atomically publish the
    /// new entry. Requests keep flowing throughout: mid-swap submits are
    /// served by whichever fully-formed entry they resolve, and the key
    /// keeps its shard so routing never migrates. Errors if `key` is not
    /// registered (or was evicted mid-swap); a failed prepare leaves the
    /// old entry serving.
    pub fn swap(&self, key: &str, m: &CsrMatrix) -> Result<Arc<RegisteredMatrix>> {
        self.registry.swap(key, m, |entry| {
            self.shards[entry.shard()]
                .backend
                .prepare(entry.solver())
                .with_context(|| format!("prepare backend for swapped matrix {key:?}"))
        })
    }

    /// Route one request to the shard owning its matrix. An unknown
    /// `matrix_key` is answered with an immediate error **reply** on the
    /// request's channel (never a hang, never a dropped request); the
    /// call itself errors only if the service is shutting down.
    pub fn route(&self, req: SolveRequest) -> Result<()> {
        // `checkout` (not `get`): the in-flight mark is taken under the
        // registry's read lock, so an evict cannot slip between the
        // lookup and the enqueue and miss this request in its drain.
        let Some(entry) = self.registry.checkout(&req.matrix_key) else {
            let _ = req.reply.send(Err(anyhow!(
                "unknown matrix key {:?} (registered: [{}])",
                req.matrix_key,
                self.registry.keys().join(", ")
            )));
            return Ok(());
        };
        // Guard the mark before anything fallible: every early return
        // below must check the request back in, or an evict of this key
        // would wait forever on a request that never ran.
        let guard = InflightGuard(entry);
        let shard = &self.shards[guard.entry().shard()];
        shard
            .tx
            .as_ref()
            .context("service stopped")?
            .send(ShardJob {
                b: req.b,
                reply: req.reply,
                guard,
            })
            .ok()
            .context("shard queue closed")?;
        Ok(())
    }

    /// Submit a request for `key`; returns the receiver for the response.
    pub fn submit(&self, key: &str, b: Vec<f32>) -> Result<mpsc::Receiver<Result<SolveResponse>>> {
        let (reply, rx) = mpsc::channel();
        self.route(SolveRequest {
            matrix_key: key.to_string(),
            b,
            reply,
        })?;
        Ok(rx)
    }

    /// Solve synchronously against the matrix registered under `key`.
    pub fn solve(&self, key: &str, b: Vec<f32>) -> Result<SolveResponse> {
        self.submit(key, b)?.recv().context("worker dropped")?
    }

    /// The matrix registry (lookups, keys, per-matrix served counts).
    pub fn registry(&self) -> &Arc<MatrixRegistry> {
        &self.registry
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Point-in-time per-shard serving statistics.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| s.counters.snapshot(i))
            .collect()
    }

    /// Aggregate serving statistics across all shards, including the
    /// worker-pool session concurrency of every **distinct** backend
    /// (shards share one backend — and so one pool — by default;
    /// `peak_concurrency >= 2` there means two solves really overlapped).
    pub fn stats(&self) -> ServingStats {
        let mut agg = ServingStats::aggregate(&self.shard_stats());
        // Dedup backends by data pointer (not `Arc::ptr_eq`, which
        // compares vtable pointers too on `dyn` and lints as ambiguous).
        let mut seen: Vec<*const ()> = Vec::new();
        for shard in &self.shards {
            let ptr = Arc::as_ptr(&shard.backend) as *const ();
            if seen.contains(&ptr) {
                continue;
            }
            seen.push(ptr);
            if let Some(pool) = shard.backend.pool_stats() {
                agg.concurrent_sessions += pool.concurrent_sessions as u64;
                agg.peak_concurrency = agg.peak_concurrency.max(pool.peak_concurrency as u64);
            }
        }
        agg
    }

    /// Replies delivered so far (successful and error replies; unknown-key
    /// replies short-circuit at routing and are not counted here).
    pub fn served(&self) -> u64 {
        let agg = self.stats();
        agg.served + agg.errors
    }

    /// Name of the numeric backend serving requests.
    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    /// Stop all shard workers (each drains its queue first). Dropping the
    /// service does the same; this form merely makes the join explicit.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        for shard in &mut self.shards {
            shard.tx.take();
        }
        for shard in &mut self.shards {
            for w in shard.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

impl Drop for ShardedSolveService {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One shard worker: drain up to `batch` jobs per round, group
/// same-matrix jobs, and dispatch each group through the backend
/// (multi-RHS when the group and backend allow it).
fn shard_worker(
    rx: &Mutex<mpsc::Receiver<ShardJob>>,
    backend: &dyn SolverBackend,
    counters: &ShardCounters,
    batch: usize,
) {
    loop {
        let mut jobs = Vec::with_capacity(batch);
        {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(j) => jobs.push(j),
                Err(_) => return, // channel closed: clean shutdown
            }
            while jobs.len() < batch {
                match guard.try_recv() {
                    Ok(j) => jobs.push(j),
                    Err(_) => break,
                }
            }
        }
        for (entry, group) in group_by_matrix(jobs) {
            solve_group(backend, &entry, group, counters);
        }
    }
}

type Reply = mpsc::Sender<Result<SolveResponse>>;

/// One same-matrix slice of a drained batch: the registry entry and the
/// `(rhs, reply, in-flight mark)` triples that target it.
type MatrixGroup = (
    Arc<RegisteredMatrix>,
    Vec<(Vec<f32>, Reply, InflightGuard)>,
);

/// Partition a drained batch into per-matrix groups (order-preserving;
/// identity is the registry entry, compared by `Arc` pointer — so jobs
/// resolved against a pre-swap entry never batch with post-swap ones).
fn group_by_matrix(jobs: Vec<ShardJob>) -> Vec<MatrixGroup> {
    let mut groups: Vec<MatrixGroup> = Vec::new();
    for job in jobs {
        match groups
            .iter_mut()
            .find(|(e, _)| Arc::ptr_eq(e, job.guard.entry()))
        {
            Some((_, g)) => g.push((job.b, job.reply, job.guard)),
            None => {
                let entry = Arc::clone(job.guard.entry());
                groups.push((entry, vec![(job.b, job.reply, job.guard)]));
            }
        }
    }
    groups
}

/// Solve one same-matrix group and reply to every requester. Errors are
/// propagated to each caller in the group — a worker must never drop
/// requests on the floor.
fn solve_group(
    backend: &dyn SolverBackend,
    entry: &RegisteredMatrix,
    group: Vec<(Vec<f32>, Reply, InflightGuard)>,
    counters: &ShardCounters,
) {
    let count = group.len();
    let t0 = Instant::now();
    if count > 1 && backend.supports_multi_rhs() {
        // Batched rounds go through the backend's multi-RHS path,
        // amortizing dispatch and gather staging. The RHS vectors move
        // out of the jobs (no clone); replies only need the channels.
        // The in-flight guards stay alive until every reply in the group
        // has been sent, so an evict observes all-or-nothing per round.
        let mut bs = Vec::with_capacity(count);
        let mut replies = Vec::with_capacity(count);
        let mut guards = Vec::with_capacity(count);
        for (b, reply, guard) in group {
            bs.push(b);
            replies.push(reply);
            guards.push(guard);
        }
        match backend.solve_multi(entry.solver(), &bs) {
            Ok(xs) => {
                let elapsed = t0.elapsed();
                let per = elapsed.as_secs_f64() / count as f64;
                entry.note_served(count as u64);
                counters.record_round(count as u64, 0, elapsed);
                for (reply, x) in replies.into_iter().zip(xs) {
                    let _ = reply.send(Ok(SolveResponse {
                        x,
                        host_seconds: per,
                        metrics: entry.metrics().clone(),
                    }));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                counters.record_round(0, count as u64, t0.elapsed());
                for reply in replies {
                    let _ = reply.send(Err(anyhow!(msg.clone())));
                }
            }
        }
        drop(guards); // replies delivered: requests leave the in-flight set
    } else {
        // Scalar path: reply immediately after each solve (no head-of-
        // group latency), recording counters just before each send so a
        // caller holding its response never reads stale stats.
        for (b, reply, guard) in group {
            let t1 = Instant::now();
            let out = backend.solve(entry.solver(), &b).map(|x| SolveResponse {
                x,
                host_seconds: t1.elapsed().as_secs_f64(),
                metrics: entry.metrics().clone(),
            });
            match &out {
                Ok(_) => {
                    entry.note_served(1);
                    counters.record_round(1, 0, t1.elapsed());
                }
                Err(_) => counters.record_round(0, 1, t1.elapsed()),
            }
            let _ = reply.send(out);
            drop(guard); // reply delivered: request leaves the in-flight set
        }
    }
}

/// Key the [`SolveService`] facade registers its single matrix under.
const SINGLE_KEY: &str = "default";

/// The single-matrix solve service: a 1-shard [`ShardedSolveService`]
/// with one matrix registered at startup. This is the compile-once,
/// serve-many facade used by `mgd solve`, tests and benches.
pub struct SolveService {
    inner: ShardedSolveService,
    /// The compiled accelerator program (public for inspection/benches).
    pub program: Arc<Program>,
    /// Shared per-matrix metrics.
    pub metrics: SolveMetrics,
}

impl SolveService {
    /// Construct the configured backend ([`create_backend`]), start a
    /// 1-shard service, and register `m`. Backend construction failures —
    /// e.g. an explicit `pjrt` request without the toolchain — are
    /// startup errors, not hung requests; so are compile/verify failures.
    pub fn start(m: &CsrMatrix, cfg: ServiceConfig) -> Result<Self> {
        let backend = create_backend(&cfg.backend).context("construct solver backend")?;
        Self::start_with_backend(m, backend, cfg)
    }

    /// Like [`SolveService::start`] but with a caller-provided backend
    /// (dependency injection for tests, benches and embedders).
    pub fn start_with_backend(
        m: &CsrMatrix,
        backend: Arc<dyn SolverBackend>,
        cfg: ServiceConfig,
    ) -> Result<Self> {
        let inner = ShardedSolveService::start_with_backend(
            backend,
            ShardedServiceConfig {
                compiler: cfg.compiler,
                shards: 1,
                workers_per_shard: cfg.workers,
                batch_size: cfg.batch_size,
                backend: cfg.backend,
                backend_per_shard: false,
            },
        );
        let entry = inner.register(SINGLE_KEY, m)?;
        let program = Arc::clone(entry.program());
        let metrics = entry.metrics().clone();
        Ok(Self {
            inner,
            program,
            metrics,
        })
    }

    /// Submit a request; returns the receiver for the response.
    pub fn submit(&self, b: Vec<f32>) -> Result<mpsc::Receiver<Result<SolveResponse>>> {
        self.inner.submit(SINGLE_KEY, b)
    }

    /// Solve synchronously (submit + wait).
    pub fn solve(&self, b: Vec<f32>) -> Result<SolveResponse> {
        self.inner.solve(SINGLE_KEY, b)
    }

    /// Replies delivered so far (successful and error replies).
    pub fn served(&self) -> u64 {
        self.inner.served()
    }

    /// Name of the numeric backend serving requests.
    pub fn backend_name(&self) -> &'static str {
        self.inner.backend_name()
    }

    /// Stop the workers (drains the queue first).
    pub fn shutdown(self) {
        self.inner.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::assert_close_to_reference;
    use crate::runtime::BackendKind;

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            compiler: CompilerConfig {
                arch: ArchConfig {
                    log2_cus: 4,
                    ..ArchConfig::default()
                },
                ..CompilerConfig::default()
            },
            workers: 2,
            batch_size: 4,
            backend: BackendConfig::default(),
        }
    }

    fn small_sharded_cfg(shards: usize) -> ShardedServiceConfig {
        ShardedServiceConfig {
            compiler: CompilerConfig {
                arch: ArchConfig {
                    log2_cus: 4,
                    ..ArchConfig::default()
                },
                ..CompilerConfig::default()
            },
            shards,
            workers_per_shard: 2,
            batch_size: 4,
            backend: BackendConfig::default(),
            backend_per_shard: false,
        }
    }

    #[test]
    fn serves_concurrent_requests_correctly() {
        let m = gen::circuit(400, 5, 0.8, GenSeed(1));
        let svc = SolveService::start(&m, small_cfg()).unwrap();
        let mut rxs = Vec::new();
        let mut bs = Vec::new();
        for k in 0..12 {
            let b: Vec<f32> = (0..m.n).map(|i| ((i + k) % 7) as f32 - 3.0).collect();
            rxs.push(svc.submit(b.clone()).unwrap());
            bs.push(b);
        }
        for (rx, b) in rxs.into_iter().zip(bs) {
            let resp = rx.recv().unwrap().unwrap();
            assert_close_to_reference(&m, &b, &resp.x, 1e-3);
            assert!(resp.metrics.gops > 0.0);
            // >= 0.0, not > 0.0: tiny solves can land under the host
            // timer's resolution.
            assert!(resp.host_seconds >= 0.0);
        }
        assert_eq!(svc.served(), 12);
        svc.shutdown();
    }

    #[test]
    fn serves_through_the_mgd_scheduler() {
        use crate::runtime::{NativeConfig, SchedulerKind};
        // A deep matrix served with the barrier-free scheduler pinned:
        // requests flow through `MgdPlan`/`mgd_exec` end to end.
        let m = gen::banded(600, 3, 0.9, GenSeed(6));
        let cfg = ServiceConfig {
            backend: BackendConfig {
                kind: BackendKind::Native,
                native: NativeConfig {
                    threads: 4,
                    scheduler: SchedulerKind::Mgd,
                    ..NativeConfig::default()
                },
                ..BackendConfig::default()
            },
            ..small_cfg()
        };
        let svc = SolveService::start(&m, cfg).unwrap();
        assert_eq!(svc.backend_name(), "native");
        let mut rxs = Vec::new();
        let mut bs = Vec::new();
        for k in 0..6 {
            let b: Vec<f32> = (0..m.n).map(|i| ((i + 2 * k) % 5) as f32 - 2.0).collect();
            rxs.push(svc.submit(b.clone()).unwrap());
            bs.push(b);
        }
        for (rx, b) in rxs.into_iter().zip(bs) {
            let resp = rx.recv().unwrap().unwrap();
            // The MGD scheduler's contract is bitwise-serial numerics.
            let want = crate::matrix::triangular::solve_serial(&m, &b);
            for i in 0..m.n {
                assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "row {i}");
            }
        }
        assert_eq!(svc.served(), 6);
        svc.shutdown();
    }

    #[test]
    fn default_backend_is_native_without_pjrt_artifacts() {
        let m = gen::banded(200, 4, 0.6, GenSeed(3));
        let svc = SolveService::start(&m, small_cfg()).unwrap();
        // Auto selection: PJRT artifacts are absent in a clean checkout,
        // so the service must come up on the native executor.
        assert_eq!(svc.backend_name(), "native");
        let resp = svc.solve(vec![1.0f32; m.n]).unwrap();
        assert_close_to_reference(&m, &vec![1.0f32; m.n], &resp.x, 1e-3);
        svc.shutdown();
    }

    #[test]
    fn explicit_pjrt_without_toolchain_fails_at_start_not_at_solve() {
        // The seed bug: a worker whose runtime failed to load returned
        // silently, so submitted requests hung forever. Backend
        // construction now happens before any worker spawns.
        let m = gen::banded(150, 4, 0.6, GenSeed(4));
        let cfg = ServiceConfig {
            backend: BackendConfig {
                kind: BackendKind::Pjrt,
                artifacts: std::path::PathBuf::from("/nonexistent/artifacts"),
                ..BackendConfig::default()
            },
            ..small_cfg()
        };
        let err = SolveService::start(&m, cfg).err().expect("must not hang");
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt") || msg.contains("PJRT"), "{msg}");
    }

    #[test]
    fn worker_replies_with_error_on_bad_request() {
        // A malformed RHS must produce an error reply, not a hang or a
        // worker exit.
        let m = gen::banded(100, 4, 0.6, GenSeed(5));
        let svc = SolveService::start(&m, small_cfg()).unwrap();
        let err = svc.solve(vec![1.0f32; m.n + 7]).unwrap_err();
        assert!(format!("{err:#}").contains("rhs length"));
        // The service keeps serving after an error round.
        let ok = svc.solve(vec![1.0f32; m.n]).unwrap();
        assert_close_to_reference(&m, &vec![1.0f32; m.n], &ok.x, 1e-3);
        svc.shutdown();
    }

    #[test]
    fn metrics_match_program_prediction() {
        let m = gen::banded(300, 5, 0.6, GenSeed(2));
        let svc = SolveService::start(&m, small_cfg()).unwrap();
        assert_eq!(svc.metrics.cycles, svc.program.predicted.cycles);
        svc.shutdown();
    }

    #[test]
    fn sharded_service_routes_multiple_matrices() {
        let svc = ShardedSolveService::start(small_sharded_cfg(2)).unwrap();
        let ma = gen::circuit(300, 4, 0.8, GenSeed(71));
        let mb = gen::banded(220, 4, 0.6, GenSeed(72));
        let ea = svc.register("alpha", &ma).unwrap();
        let eb = svc.register("beta", &mb).unwrap();
        // Two matrices on two shards: round-robin assignment.
        assert_eq!((ea.shard(), eb.shard()), (0, 1));
        let mut expect = Vec::new();
        let mut rxs = Vec::new();
        for k in 0..10 {
            let (key, m) = if k % 2 == 0 { ("alpha", &ma) } else { ("beta", &mb) };
            let b: Vec<f32> = (0..m.n).map(|i| ((i + k) % 7) as f32 - 3.0).collect();
            rxs.push(svc.submit(key, b.clone()).unwrap());
            expect.push((m, b));
        }
        for (rx, (m, b)) in rxs.into_iter().zip(expect) {
            let resp = rx.recv().unwrap().unwrap();
            assert_close_to_reference(m, &b, &resp.x, 1e-3);
        }
        // Both shards served, and the aggregate adds up.
        let stats = svc.shard_stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].served, 5, "{stats:?}");
        assert_eq!(stats[1].served, 5, "{stats:?}");
        let agg = svc.stats();
        assert_eq!(agg.served, 10);
        assert_eq!(agg.errors, 0);
        assert!(agg.batched_rounds >= 2);
        assert_eq!(ea.served() + eb.served(), 10);
        svc.shutdown();
    }

    #[test]
    fn unknown_matrix_key_is_an_error_reply_not_a_hang() {
        let svc = ShardedSolveService::start(small_sharded_cfg(2)).unwrap();
        let m = gen::chain(80, GenSeed(73));
        svc.register("only", &m).unwrap();
        // Reply arrives immediately with a diagnostic, listing what is
        // actually registered.
        let err = svc.solve("missing", vec![0.0; m.n]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown matrix key"), "{msg}");
        assert!(msg.contains("only"), "{msg}");
        // The error does not count against any shard's request stream.
        assert_eq!(svc.stats().errors, 0);
        svc.shutdown();
    }

    #[test]
    fn failed_prepare_rolls_back_the_registration() {
        use crate::runtime::LevelSolver;
        struct FailingPrepare;
        impl SolverBackend for FailingPrepare {
            fn name(&self) -> &'static str {
                "failing"
            }
            fn prepare(&self, _plan: &LevelSolver) -> Result<()> {
                anyhow::bail!("artifacts unavailable")
            }
            fn solve(&self, plan: &LevelSolver, b: &[f32]) -> Result<Vec<f32>> {
                Ok(crate::matrix::triangular::solve_serial(plan.matrix(), b))
            }
        }
        let svc =
            ShardedSolveService::start_with_backend(Arc::new(FailingPrepare), small_sharded_cfg(1));
        let m = gen::chain(50, GenSeed(75));
        let err = svc.register("m", &m).unwrap_err();
        assert!(format!("{err:#}").contains("prepare backend"));
        // The key is not poisoned: it is unknown again and can be
        // registered against a working backend later.
        assert!(svc.registry().get("m").is_none());
        svc.shutdown();
    }

    #[test]
    fn duplicate_registration_errors() {
        let svc = ShardedSolveService::start(small_sharded_cfg(1)).unwrap();
        let m = gen::chain(60, GenSeed(74));
        svc.register("m", &m).unwrap();
        assert!(svc.register("m", &m).is_err());
        svc.shutdown();
    }

    #[test]
    fn evict_retires_the_key_and_frees_it_for_reregistration() {
        let svc = ShardedSolveService::start(small_sharded_cfg(2)).unwrap();
        let m = gen::banded(200, 4, 0.6, GenSeed(76));
        svc.register("cold", &m).unwrap();
        let resp = svc.solve("cold", vec![1.0; m.n]).unwrap();
        assert_close_to_reference(&m, &vec![1.0; m.n], &resp.x, 1e-3);
        let entry = svc.evict("cold").unwrap();
        assert_eq!(entry.served(), 1);
        assert_eq!(entry.inflight(), 0, "evict returned before draining");
        // The key is unknown now (error reply, not a hang)...
        let err = svc.solve("cold", vec![1.0; m.n]).unwrap_err();
        assert!(format!("{err:#}").contains("unknown matrix key"));
        // ...an evict of an unknown key is an error...
        assert!(svc.evict("cold").is_err());
        // ...and the key can be registered again.
        svc.register("cold", &m).unwrap();
        assert!(svc.solve("cold", vec![1.0; m.n]).is_ok());
        svc.shutdown();
    }

    #[test]
    fn swap_replaces_the_matrix_between_requests() {
        let svc = ShardedSolveService::start(small_sharded_cfg(2)).unwrap();
        let ma = gen::banded(180, 4, 0.6, GenSeed(77));
        let mb = gen::banded(240, 5, 0.7, GenSeed(78));
        let old = svc.register("hot", &ma).unwrap();
        let ra = svc.solve("hot", vec![1.0; ma.n]).unwrap();
        assert_close_to_reference(&ma, &vec![1.0; ma.n], &ra.x, 1e-3);
        // Swap to a different matrix (different order, even): the key
        // stays routable throughout and keeps its shard.
        let new = svc.swap("hot", &mb).unwrap();
        assert_eq!(new.shard(), old.shard());
        assert_eq!(new.served(), 1, "served carries across the swap");
        let rb = svc.solve("hot", vec![1.0; mb.n]).unwrap();
        assert_eq!(rb.x.len(), mb.n);
        assert_close_to_reference(&mb, &vec![1.0; mb.n], &rb.x, 1e-3);
        assert_eq!(new.served(), 2);
        // Swapping an unknown key errors without disturbing the rest.
        assert!(svc.swap("ghost", &ma).is_err());
        svc.shutdown();
    }
}
