//! Offline stand-in for the [`anyhow`](https://crates.io/crates/anyhow)
//! crate, clean-room implemented for the subset this workspace uses.
//!
//! The build image has no crates.io access, so the workspace vendors this
//! drop-in: a context-chain error type, the [`Context`] extension trait for
//! `Result` and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Error display follows anyhow's convention: `{}` prints the outermost
//! context, `{:#}` prints the whole chain separated by `: `.

use std::fmt;

/// A string-chain error: the outermost context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that keeps this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    /// Attach a context message to the error (or to a missing `Option`).
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Lazily attach a context message to the error.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built from the arguments.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("read manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "read manifest");
        assert_eq!(format!("{e:#}"), "read manifest: file missing");
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let r: Result<u32, Error> = Ok::<_, std::io::Error>(7)
            .with_context(|| -> String { panic!("must not be called") });
        assert_eq!(r.unwrap(), 7);
    }

    #[test]
    fn option_context() {
        let r: Result<u32> = None.context("missing field");
        assert_eq!(format!("{}", r.unwrap_err()), "missing field");
        let r: Result<u32> = Some(3).context("unused");
        assert_eq!(r.unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("x").is_err());
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fallthrough {}", x))
        }
        assert!(format!("{}", f(12).unwrap_err()).contains("12"));
        assert!(format!("{}", f(5).unwrap_err()).contains("five"));
        assert!(format!("{}", f(1).unwrap_err()).contains('1'));
        let owned = String::from("owned message");
        assert_eq!(format!("{}", anyhow!(owned)), "owned message");
    }

    #[test]
    fn bare_ensure_names_the_condition() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(format!("{}", f().unwrap_err()).contains("1 + 1 == 3"));
    }
}
