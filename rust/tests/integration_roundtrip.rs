//! Cross-module integration: randomized compile → simulate → verify sweeps
//! (property-style: the offline image has no proptest, so invariants are
//! driven by a seeded in-house PRNG across many random configurations).

use mgd_sptrsv::arch::ArchConfig;
use mgd_sptrsv::compiler::{compile, AllocationPolicy, CompilerConfig};
use mgd_sptrsv::matrix::gen::{self, GenSeed};
use mgd_sptrsv::matrix::triangular::assert_close_to_reference;
use mgd_sptrsv::matrix::CsrMatrix;
use mgd_sptrsv::sim::Accelerator;
use mgd_sptrsv::util::XorShift64;

fn random_matrix(rng: &mut XorShift64) -> CsrMatrix {
    let n = rng.range(20, 600);
    match rng.below(6) {
        0 => gen::chain(n, GenSeed(rng.next_u64())),
        1 => gen::banded(n, rng.range(2, 12), 0.3 + rng.f64() * 0.6, GenSeed(rng.next_u64())),
        2 => gen::circuit(n, rng.range(2, 8), 0.5 + rng.f64() * 0.4, GenSeed(rng.next_u64())),
        3 => {
            let side = ((n as f64).sqrt() as usize).max(2);
            gen::grid2d(side, side, rng.chance(0.5), GenSeed(rng.next_u64()))
        }
        4 => gen::power_law(n, 1.05 + rng.f64(), rng.range(8, 64), GenSeed(rng.next_u64())),
        _ => gen::shallow(n, rng.f64() * 0.6, GenSeed(rng.next_u64())),
    }
}

fn random_config(rng: &mut XorShift64) -> CompilerConfig {
    CompilerConfig {
        arch: ArchConfig {
            log2_cus: rng.range(1, 6) as u32,
            log2_xi_words: rng.range(2, 7) as u32,
            psum_words: rng.below(10) as u32,
            ..ArchConfig::default()
        },
        allocation: if rng.chance(0.5) {
            AllocationPolicy::RoundRobin
        } else {
            AllocationPolicy::LeastLoaded
        },
        use_icr: rng.chance(0.7),
        use_coloring: rng.chance(0.7),
        forwarding: rng.chance(0.8),
    }
}

/// The master property: for ANY matrix and ANY architecture configuration,
/// compile → simulate must (1) reproduce the compiler's predicted cycle
/// counts exactly and (2) match the serial reference numerically.
#[test]
fn property_compile_simulate_verify() {
    let mut rng = XorShift64::new(0xFEED);
    for trial in 0..40 {
        let m = random_matrix(&mut rng);
        let cfg = random_config(&mut rng);
        let prog = compile(&m, &cfg)
            .unwrap_or_else(|e| panic!("trial {trial}: compile failed: {e:#}"));
        let b: Vec<f32> = (0..m.n)
            .map(|_| rng.f32_range(-4.0, 4.0))
            .collect();
        let mut acc = Accelerator::new(cfg.arch);
        let run = acc
            .run(&prog, &b)
            .unwrap_or_else(|e| panic!("trial {trial}: sim failed: {e:#}"));
        run.stats
            .verify_against(&prog.predicted)
            .unwrap_or_else(|e| panic!("trial {trial}: double-entry failed: {e:#}"));
        assert_close_to_reference(&m, &b, &run.x, 2e-3);
    }
}

/// Schedule legality across random configs: op-slot conservation and
/// utilization bounds.
#[test]
fn property_op_conservation() {
    let mut rng = XorShift64::new(0xBEEF);
    for _ in 0..25 {
        let m = random_matrix(&mut rng);
        let cfg = random_config(&mut rng);
        let prog = compile(&m, &cfg).unwrap();
        let p = prog.predicted;
        assert_eq!(p.macs as usize, m.off_diag_nnz());
        assert_eq!(p.finals as usize, m.n);
        let slots = p.cycles * cfg.arch.num_cus() as u64;
        assert_eq!(p.exec + p.bnop + p.pnop + p.dnop + p.lnop, slots);
        assert!(p.utilization(cfg.arch.num_cus()) <= 1.0);
    }
}

/// The encoded instruction streams must round-trip bit-exactly.
#[test]
fn property_isa_roundtrip_on_real_programs() {
    use mgd_sptrsv::compiler::isa::Instr;
    let mut rng = XorShift64::new(0xCAFE);
    for _ in 0..6 {
        let m = random_matrix(&mut rng);
        let cfg = random_config(&mut rng);
        let prog = compile(&m, &cfg).unwrap();
        for row in &prog.instrs {
            for ins in row {
                assert_eq!(Instr::decode(ins.encode()), *ins);
            }
        }
    }
}

/// Multiple RHS against one program (the transient-simulation pattern).
#[test]
fn many_rhs_one_program() {
    let m = gen::circuit(400, 5, 0.8, GenSeed(7));
    let cfg = CompilerConfig::default();
    let prog = compile(&m, &cfg).unwrap();
    let mut acc = Accelerator::new(cfg.arch);
    for k in 0..8 {
        let b: Vec<f32> = (0..m.n).map(|i| ((i * k) % 17) as f32 - 8.0).collect();
        let run = acc.run(&prog, &b).unwrap();
        assert_close_to_reference(&m, &b, &run.x, 1e-3);
    }
}

/// Medium-node splitting (extension): split + compile + simulate + extract.
#[test]
fn split_extension_end_to_end() {
    let m = gen::power_law(500, 1.15, 150, GenSeed(9));
    let split = mgd_sptrsv::compiler::split::split_heavy_nodes(&m, 12).unwrap();
    assert!(split.intermediates > 0);
    let cfg = CompilerConfig::default();
    let prog = compile(&split.matrix, &cfg).unwrap();
    let b: Vec<f32> = (0..m.n).map(|i| (i % 5) as f32).collect();
    let xb = split.expand_b(&b);
    let mut acc = Accelerator::new(cfg.arch);
    let run = acc.run(&prog, &xb).unwrap();
    let x = split.extract_x(&run.x);
    assert_close_to_reference(&m, &b, &x, 5e-3);
}

/// Failure injection: corrupted instruction streams must be rejected by
/// the simulator's consistency checks, not silently produce garbage.
#[test]
fn corrupted_program_detected() {
    let m = gen::banded(120, 4, 0.6, GenSeed(11));
    let cfg = CompilerConfig::default();
    let prog = compile(&m, &cfg).unwrap();
    let b = vec![1.0f32; m.n];

    // Flip an exec into a nop: stream underrun or drain check must fire.
    let mut bad = prog.clone();
    'outer: for row in bad.instrs.iter_mut() {
        for ins in row.iter_mut() {
            if ins.exec {
                *ins = mgd_sptrsv::compiler::isa::Instr::nop(
                    mgd_sptrsv::compiler::isa::NopKind::Dnop,
                );
                break 'outer;
            }
        }
    }
    let mut acc = Accelerator::new(cfg.arch);
    assert!(acc.run(&bad, &b).is_err(), "corruption must be detected");
}
