//! Session-level battery for the streaming solve path: interleaved
//! [`SolveSession`]s across the full generator suite must stream replies
//! that are **bitwise-identical** to the serial reference, for every
//! combination of backend thread count and in-session pipeline depth —
//! including a hot swap landing mid-stream, which a session must absorb
//! as exactly one epoch boundary (pre-boundary replies match the pre- or
//! post-swap reference exactly, post-boundary replies the new one).
//!
//! [`SolveSession`]: mgd_sptrsv::coordinator::SolveSession

use mgd_sptrsv::coordinator::{ShardedServiceConfig, ShardedSolveService};
use mgd_sptrsv::matrix::gen::{self, GenSeed};
use mgd_sptrsv::matrix::triangular::solve_serial;
use mgd_sptrsv::matrix::CsrMatrix;
use mgd_sptrsv::runtime::{BackendConfig, BackendKind, NativeConfig, SchedulerKind};

fn cfg(shards: usize, threads: usize) -> ShardedServiceConfig {
    ShardedServiceConfig {
        shards,
        workers_per_shard: 2,
        batch_size: 4,
        backend: BackendConfig {
            kind: BackendKind::Native,
            native: NativeConfig {
                threads,
                scheduler: SchedulerKind::Mgd,
                ..NativeConfig::default()
            },
            ..BackendConfig::default()
        },
        ..ShardedServiceConfig::default()
    }
}

/// The eight generator families (`gen::test_suite` is `cfg(test)`-only,
/// so the parameters are inlined here). Index [`SHALLOW`] is the family
/// the swap test hot-swaps mid-stream.
fn families() -> Vec<(&'static str, CsrMatrix)> {
    vec![
        ("banded", gen::banded(500, 6, 0.5, GenSeed(1))),
        ("chain", gen::chain(120, GenSeed(2))),
        ("circuit", gen::circuit(600, 5, 0.8, GenSeed(3))),
        ("grid2d", gen::grid2d(20, 20, true, GenSeed(4))),
        ("shallow", gen::shallow(900, 0.4, GenSeed(5))),
        ("random_lower", gen::random_lower(400, 2000, GenSeed(6))),
        ("power_law", gen::power_law(400, 1.1, 120, GenSeed(7))),
        ("factor_like", gen::factor_like(500, 8, 4, GenSeed(8))),
    ]
}

const SHALLOW: usize = 4;
const STEPS: usize = 6;
const SWAP_AT: usize = 3;

fn xorshift(state: &mut u64) -> u64 {
    *state ^= *state << 13;
    *state ^= *state >> 7;
    *state ^= *state << 17;
    *state
}

/// Deterministic per-(family, depth, step) RHS so every run replays the
/// same stream.
fn rhs(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    (0..n)
        .map(|_| ((xorshift(&mut s) % 9) as f32) - 4.0)
        .collect()
}

fn bitwise_eq(x: &[f32], want: &[f32]) -> bool {
    x.len() == want.len() && x.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits())
}

/// One interleaved stream: a session per family, round-robin submits,
/// the shallow key swapped to `new_shallow` before step [`SWAP_AT`].
fn run_one_stream(
    svc: &ShardedSolveService,
    fams: &[(&'static str, CsrMatrix)],
    depth: usize,
    old_shallow: &CsrMatrix,
    new_shallow: &CsrMatrix,
) {
    let mut sessions: Vec<_> = fams
        .iter()
        .map(|(key, _)| svc.open_session(key, depth).unwrap())
        .collect();
    let mut bs: Vec<Vec<Vec<f32>>> = vec![Vec::new(); fams.len()];
    for step in 0..STEPS {
        if step == SWAP_AT {
            svc.swap("shallow", new_shallow).unwrap();
        }
        for (f, (_, m)) in fams.iter().enumerate() {
            let seed = ((depth as u64) << 32) | ((f as u64) << 8) | (step as u64);
            let b = rhs(m.n, seed);
            sessions[f].submit(b.clone()).unwrap();
            bs[f].push(b);
        }
    }
    for (f, (key, m)) in fams.iter().enumerate() {
        let replies = sessions[f].drain();
        assert_eq!(replies.len(), STEPS, "{key} depth {depth}");
        assert_eq!(sessions[f].submitted(), STEPS as u64);
        if f == SHALLOW {
            assert_eq!(
                sessions[f].epoch(),
                1,
                "one swap must land as exactly one epoch boundary (depth {depth})"
            );
            for (step, reply) in replies.into_iter().enumerate() {
                let x = reply.unwrap().x;
                let is_old = bitwise_eq(&x, &solve_serial(old_shallow, &bs[f][step]));
                let is_new = bitwise_eq(&x, &solve_serial(new_shallow, &bs[f][step]));
                if step >= SWAP_AT {
                    assert!(
                        is_new,
                        "step {step} was submitted after the swap published, so it must \
                         resolve the new matrix exactly (depth {depth})"
                    );
                } else {
                    assert!(
                        is_old || is_new,
                        "step {step} reply matches neither lineage bitwise — torn \
                         epoch boundary? (depth {depth})"
                    );
                }
            }
        } else {
            for (step, reply) in replies.into_iter().enumerate() {
                let x = reply.unwrap().x;
                assert!(
                    bitwise_eq(&x, &solve_serial(m, &bs[f][step])),
                    "{key} step {step} depth {depth} diverged from the serial reference"
                );
            }
        }
    }
}

#[test]
fn streaming_sessions_match_reference() {
    let shallow_b = gen::shallow(900, 0.4, GenSeed(55));
    for threads in [1usize, 2, 8] {
        let svc = ShardedSolveService::start(cfg(2, threads)).unwrap();
        let mut fams = families();
        let shallow_a = fams[SHALLOW].1.clone();
        for (key, m) in &fams {
            svc.register(key, m).unwrap();
        }
        // Alternate the swap target across depth runs so the old and new
        // lineages always hold *different* matrices (same sparsity
        // order, different values — a torn mix matches neither).
        for (run, depth) in [1usize, 2, 8].into_iter().enumerate() {
            let old_shallow = fams[SHALLOW].1.clone();
            let new_shallow = if run % 2 == 0 {
                shallow_b.clone()
            } else {
                shallow_a.clone()
            };
            run_one_stream(&svc, &fams, depth, &old_shallow, &new_shallow);
            fams[SHALLOW].1 = new_shallow;
        }
        svc.shutdown();
    }
}

#[test]
fn session_submit_after_evict_errors_cleanly() {
    let svc = ShardedSolveService::start(cfg(1, 2)).unwrap();
    let m = gen::chain(80, GenSeed(21));
    svc.register("gone", &m).unwrap();
    let mut session = svc.open_session("gone", 2).unwrap();
    let b = vec![1.0f32; m.n];
    session.submit(b.clone()).unwrap();
    // Evict drains the in-flight solve, then unmaps the key.
    svc.evict("gone").unwrap();
    let err = session.submit(b.clone()).unwrap_err();
    assert!(format!("{err:#}").contains("evicted"), "{err:#}");
    // The reply earned before the evict stays collectable and correct.
    let x = session
        .next_reply()
        .expect("pre-evict reply must survive")
        .unwrap()
        .x;
    assert!(bitwise_eq(&x, &solve_serial(&m, &b)));
    assert!(session.next_reply().is_none(), "nothing else outstanding");
    drop(session);
    svc.shutdown();
}

#[test]
fn open_session_unknown_key_lists_registered_keys() {
    let svc = ShardedSolveService::start(cfg(1, 2)).unwrap();
    let m = gen::chain(40, GenSeed(22));
    svc.register("only", &m).unwrap();
    let err = svc.open_session("nope", 2).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("unknown matrix key") && msg.contains("only"),
        "{msg}"
    );
    svc.shutdown();
}
