//! Lifecycle edge cases of the multi-matrix registry, driven through the
//! public `ShardedSolveService` API:
//!
//! - evicting a key with requests **in flight** blocks until every
//!   routed request has been replied to (and the reply is correct);
//! - a live hot swap under concurrent submitters never produces a torn
//!   or wrong reply — every response is bitwise-identical to the serial
//!   reference of either the pre-swap or the post-swap matrix, and
//!   post-swap requests resolve the new matrix exactly;
//! - an evicted key can be registered again (and duplicates still
//!   error while a key is live);
//! - the reply contract of the admission front end: the shutdown race
//!   answers with a descriptive error reply (never a dead completion
//!   cell), a shed request's reply names the queue cap, and a
//!   `wait_timeout` that expires leaves the request — and its in-flight
//!   accounting toward `evict` — fully intact; an expired waiter can
//!   then re-arm through `on_ready` and still observe the reply.

use mgd_sptrsv::coordinator::completion::{self, PollState};
use mgd_sptrsv::coordinator::{
    Admission, AdmissionPolicy, ShardedServiceConfig, ShardedSolveService, SolveRequest,
};
use mgd_sptrsv::matrix::gen::{self, GenSeed};
use mgd_sptrsv::matrix::triangular::solve_serial;
use mgd_sptrsv::runtime::{LevelSolver, NativeConfig, SchedulerKind, SolverBackend};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

fn cfg(shards: usize) -> ShardedServiceConfig {
    ShardedServiceConfig {
        shards,
        workers_per_shard: 2,
        batch_size: 4,
        backend: mgd_sptrsv::runtime::BackendConfig {
            kind: mgd_sptrsv::runtime::BackendKind::Native,
            native: NativeConfig {
                threads: 4,
                scheduler: SchedulerKind::Mgd,
                ..NativeConfig::default()
            },
            ..mgd_sptrsv::runtime::BackendConfig::default()
        },
        ..ShardedServiceConfig::default()
    }
}

/// A backend whose solves block until released — the deterministic way
/// to hold a request "in flight" while the test pokes at the registry.
struct GatedBackend {
    started: mpsc::Sender<()>,
    release: Mutex<mpsc::Receiver<()>>,
    gate_open: AtomicBool,
}

impl GatedBackend {
    fn new() -> (Arc<Self>, mpsc::Receiver<()>, mpsc::Sender<()>) {
        let (started_tx, started_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel();
        (
            Arc::new(Self {
                started: started_tx,
                release: Mutex::new(release_rx),
                gate_open: AtomicBool::new(false),
            }),
            started_rx,
            release_tx,
        )
    }
}

impl SolverBackend for GatedBackend {
    fn name(&self) -> &'static str {
        "gated"
    }

    fn solve(&self, plan: &LevelSolver, b: &[f32]) -> anyhow::Result<Vec<f32>> {
        if !self.gate_open.load(Ordering::SeqCst) {
            let _ = self.started.send(());
            // Block until the test releases the gate; stay open after
            // that so drains and later solves run through.
            let _ = self
                .release
                .lock()
                .unwrap()
                .recv_timeout(Duration::from_secs(30));
            self.gate_open.store(true, Ordering::SeqCst);
        }
        Ok(solve_serial(plan.matrix(), b))
    }
}

#[test]
fn evict_blocks_until_inflight_requests_are_replied() {
    let (backend, started, release) = GatedBackend::new();
    let svc = Arc::new(ShardedSolveService::start_with_backend(
        backend,
        ShardedServiceConfig {
            workers_per_shard: 1,
            ..cfg(1)
        },
    ));
    let m = gen::banded(150, 4, 0.6, GenSeed(120));
    svc.register("busy", &m).unwrap();
    let b = vec![1.0f32; m.n];
    let reply = svc.submit("busy", b.clone()).unwrap();
    // Wait until the solve is genuinely inside the backend.
    started
        .recv_timeout(Duration::from_secs(30))
        .expect("solve never started");
    assert_eq!(svc.registry().get("busy").unwrap().inflight(), 1);
    // Evict from another thread: it must not return while the request
    // is being served.
    let (evicted_tx, evicted_rx) = mpsc::channel();
    let svc2 = Arc::clone(&svc);
    let evictor = std::thread::spawn(move || {
        let entry = svc2.evict("busy").unwrap();
        evicted_tx.send(entry.served()).unwrap();
    });
    assert!(
        evicted_rx.recv_timeout(Duration::from_millis(300)).is_err(),
        "evict returned while a request was in flight"
    );
    // The key is unmapped promptly even while the drain still waits...
    let mut spins = 0u64;
    while svc.registry().get("busy").is_some() {
        std::thread::yield_now();
        spins += 1;
        assert!(spins < 50_000_000, "evict never unmapped the key");
    }
    // ...so new submits get the unknown-key error reply immediately.
    let err = svc.solve("busy", b.clone()).unwrap_err();
    assert!(format!("{err:#}").contains("unknown matrix key"), "{err:#}");
    // Release the gate: the in-flight request completes (correctly),
    // and only then does the evict return.
    release.send(()).unwrap();
    let resp = reply
        .wait_timeout(Duration::from_secs(30))
        .expect("reply must arrive")
        .unwrap();
    let want = solve_serial(&m, &b);
    for i in 0..m.n {
        assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "row {i}");
    }
    let served = evicted_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("evict never finished after the drain");
    assert_eq!(served, 1, "drained entry saw its request through");
    evictor.join().unwrap();
    // Duplicate re-registration after evict: the key is free again, and
    // duplicates error once it is live.
    svc.register("busy", &m).unwrap();
    assert!(svc.register("busy", &m).is_err());
    let resp = svc.solve("busy", b.clone()).unwrap();
    for i in 0..m.n {
        assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "post-evict row {i}");
    }
    Arc::try_unwrap(svc).ok().expect("sole owner").shutdown();
}

#[test]
fn swap_under_concurrent_submitters_is_never_torn() {
    // Same order, different values: a reply computed from a torn mix of
    // the two entries matches neither reference bitwise.
    let ma = gen::shallow(900, 0.4, GenSeed(121));
    let mb = gen::shallow(900, 0.4, GenSeed(122));
    assert_eq!(ma.n, mb.n);
    let svc = Arc::new(ShardedSolveService::start(cfg(2)).unwrap());
    svc.register("hot", &ma).unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let mut submitters = Vec::new();
    for t in 0..4u64 {
        let svc = Arc::clone(&svc);
        let stop = Arc::clone(&stop);
        let (ma, mb) = (ma.clone(), mb.clone());
        submitters.push(std::thread::spawn(move || {
            let mut round = 0u64;
            let mut matched_old = 0u64;
            let mut matched_new = 0u64;
            while !stop.load(Ordering::SeqCst) {
                let b: Vec<f32> = (0..ma.n)
                    .map(|i| ((i as u64 + 3 * t + round) % 9) as f32 - 4.0)
                    .collect();
                let resp = svc.solve("hot", b.clone()).unwrap();
                let want_old = solve_serial(&ma, &b);
                let want_new = solve_serial(&mb, &b);
                let is_old = (0..ma.n).all(|i| resp.x[i].to_bits() == want_old[i].to_bits());
                let is_new = (0..mb.n).all(|i| resp.x[i].to_bits() == want_new[i].to_bits());
                assert!(
                    is_old || is_new,
                    "reply matches neither pre- nor post-swap matrix bitwise (torn swap?)"
                );
                if is_old {
                    matched_old += 1;
                } else {
                    matched_new += 1;
                }
                round += 1;
            }
            (matched_old, matched_new)
        }));
    }
    // Let traffic flow, swap mid-stream, let more traffic flow.
    std::thread::sleep(Duration::from_millis(100));
    let new_entry = svc.swap("hot", &mb).unwrap();
    assert_eq!(new_entry.solver().n(), mb.n);
    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, Ordering::SeqCst);
    let mut total_old = 0u64;
    let mut total_new = 0u64;
    for s in submitters {
        let (o, n) = s.join().unwrap();
        total_old += o;
        total_new += n;
    }
    assert!(total_old + total_new > 0, "no traffic flowed");
    // After the swap is published, fresh requests must resolve the new
    // matrix exactly.
    let b: Vec<f32> = (0..mb.n).map(|i| (i % 7) as f32 - 3.0).collect();
    let resp = svc.solve("hot", b.clone()).unwrap();
    let want = solve_serial(&mb, &b);
    for i in 0..mb.n {
        assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "post-swap row {i}");
    }
    // Lifetime served counter: everything above landed on the one key.
    assert_eq!(
        svc.registry().get("hot").unwrap().served(),
        total_old + total_new + 1
    );
    Arc::try_unwrap(svc).ok().expect("sole owner").shutdown();
}

#[test]
fn shutdown_race_sends_a_descriptive_error_reply() {
    // The seed bug: when a shard queue was already closed, `route`
    // dropped the reply channel without answering, so waiters saw a bare
    // RecvError instead of the promised error reply.
    let svc = ShardedSolveService::start(cfg(1)).unwrap();
    let m = gen::chain(60, GenSeed(124));
    svc.register("late", &m).unwrap();
    svc.close_intake();
    let (reply, rx) = completion::channel();
    let err = svc
        .route(SolveRequest {
            matrix_key: "late".to_string(),
            b: vec![1.0; m.n],
            reply,
            class: None,
        })
        .expect_err("routing into a closed service must error");
    assert!(format!("{err:#}").contains("service stopped"), "{err:#}");
    // The waiter's side: a real reply, not an abandoned completion cell.
    let replied = match rx.wait_timeout(Duration::from_secs(5)) {
        PollState::Ready(reply) => reply.expect_err("the reply must be the shutdown error"),
        other => panic!("reply contract broken: {other:?} instead of an error reply"),
    };
    assert!(
        format!("{replied:#}").contains("accepts no new requests"),
        "{replied:#}"
    );
    // The refused request checked back in: evict has nothing to drain.
    let entry = svc.evict("late").unwrap();
    assert_eq!(entry.inflight(), 0);
    svc.shutdown();
}

#[test]
fn shed_reply_carries_the_queue_cap_reason() {
    let (backend, started, release) = GatedBackend::new();
    let svc = ShardedSolveService::start_with_backend(
        backend,
        ShardedServiceConfig {
            workers_per_shard: 1,
            queue_cap: 1,
            admission: AdmissionPolicy::Shed,
            ..cfg(1)
        },
    );
    let m = gen::banded(120, 4, 0.6, GenSeed(125));
    svc.register("capped", &m).unwrap();
    let b = vec![1.0f32; m.n];
    // First request occupies the worker inside the gate; second fills
    // the single-slot bulk lane.
    let h0 = svc.submit("capped", b.clone()).unwrap();
    started
        .recv_timeout(Duration::from_secs(30))
        .expect("solve never started");
    let h1 = svc.submit("capped", b.clone()).unwrap();
    // Third: shed. try_route reports the verdict with the reason...
    match svc.try_route("capped", b.clone(), None).unwrap() {
        Admission::Shed(reason) => {
            assert!(reason.contains("queue cap"), "{reason}");
            assert!(reason.contains("1 slots"), "cap value missing: {reason}");
        }
        Admission::Admitted(_) => panic!("third request must shed at cap 1"),
    }
    // ...and the submit form delivers the same reason as an error reply.
    let err = svc
        .submit("capped", b.clone())
        .unwrap()
        .wait()
        .expect_err("shed request must get an error reply");
    let msg = format!("{err:#}");
    assert!(msg.contains("shed") && msg.contains("queue cap"), "{msg}");
    release.send(()).unwrap();
    for h in [h0, h1] {
        let resp = h
            .wait_timeout(Duration::from_secs(30))
            .expect("admitted reply must arrive")
            .unwrap();
        let want = solve_serial(&m, &b);
        for i in 0..m.n {
            assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "row {i}");
        }
    }
    let stats = svc.stats();
    assert_eq!(stats.shed_bulk, 2, "{stats:?}");
    assert!(stats.peak_queue_depth <= 1, "{stats:?}");
    svc.shutdown();
}

#[test]
fn wait_timeout_expiry_keeps_the_request_and_its_inflight_accounting() {
    let (backend, started, release) = GatedBackend::new();
    let svc = Arc::new(ShardedSolveService::start_with_backend(
        backend,
        ShardedServiceConfig {
            workers_per_shard: 1,
            ..cfg(1)
        },
    ));
    let m = gen::banded(150, 4, 0.6, GenSeed(126));
    svc.register("slow", &m).unwrap();
    let b = vec![1.0f32; m.n];
    let handle = match svc.try_route("slow", b.clone(), None).unwrap() {
        Admission::Admitted(h) => h,
        Admission::Shed(r) => panic!("nothing should shed on an empty queue: {r}"),
    };
    started
        .recv_timeout(Duration::from_secs(30))
        .expect("solve never started");
    // Deadline expires while the backend still holds the solve: the
    // caller gets its timeout, the request stays in flight.
    assert!(
        handle.wait_timeout(Duration::from_millis(100)).is_none(),
        "gated solve finished implausibly fast"
    );
    assert_eq!(
        svc.registry().get("slow").unwrap().inflight(),
        1,
        "timeout must not release the in-flight guard"
    );
    // An evict started now must still block on that request...
    let svc2 = Arc::clone(&svc);
    let evictor = std::thread::spawn(move || svc2.evict("slow").unwrap());
    std::thread::sleep(Duration::from_millis(100));
    assert!(
        !evictor.is_finished(),
        "evict returned while the timed-out request was still in flight"
    );
    // ...and after release, the same handle still receives the reply.
    release.send(()).unwrap();
    let resp = handle
        .wait_timeout(Duration::from_secs(30))
        .expect("reply must survive an earlier timeout")
        .unwrap();
    let want = solve_serial(&m, &b);
    for i in 0..m.n {
        assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "row {i}");
    }
    let drained = evictor.join().unwrap();
    assert_eq!(drained.inflight(), 0);
    assert_eq!(drained.served(), 1);
    Arc::try_unwrap(svc).ok().expect("sole owner").shutdown();
}

#[test]
fn wait_timeout_expiry_then_on_ready_rearming_still_completes() {
    // Regression for the completion layer's rendezvous: a waiter whose
    // deadline expired must be able to re-arm through `on_ready` and
    // still observe the reply — the expiry must consume neither the
    // value nor the registration slot, and the in-flight accounting
    // toward `evict` stays exact throughout.
    let (backend, started, release) = GatedBackend::new();
    let svc = ShardedSolveService::start_with_backend(
        backend,
        ShardedServiceConfig {
            workers_per_shard: 1,
            ..cfg(1)
        },
    );
    let m = gen::banded(150, 4, 0.6, GenSeed(127));
    svc.register("rearm", &m).unwrap();
    let b = vec![1.0f32; m.n];
    let handle = match svc.try_route("rearm", b.clone(), None).unwrap() {
        Admission::Admitted(h) => h,
        Admission::Shed(r) => panic!("nothing should shed on an empty queue: {r}"),
    };
    started
        .recv_timeout(Duration::from_secs(30))
        .expect("solve never started");
    // 1. The deadline expires while the backend still holds the solve.
    assert!(
        handle.wait_timeout(Duration::from_millis(100)).is_none(),
        "gated solve finished implausibly fast"
    );
    assert_eq!(svc.registry().get("rearm").unwrap().inflight(), 1);
    // 2. Re-arm through `on_ready`: the registration must stick even
    // though an earlier waiter already timed out on this cell...
    let (fired_tx, fired_rx) = mpsc::channel();
    handle.on_ready(move || {
        let _ = fired_tx.send(());
    });
    // ...and must not fire before the reply exists.
    assert!(
        fired_rx.recv_timeout(Duration::from_millis(100)).is_err(),
        "waker fired before the reply exists"
    );
    // 3. Release the gate: the waker fires, and the same handle yields
    // the bitwise-correct reply.
    release.send(()).unwrap();
    fired_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("on_ready waker never fired after an earlier wait_timeout expiry");
    let resp = handle
        .wait_timeout(Duration::from_secs(30))
        .expect("reply must survive the timeout/re-arm sequence")
        .unwrap();
    let want = solve_serial(&m, &b);
    for i in 0..m.n {
        assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "row {i}");
    }
    // 4. Accounting closed out: evict has nothing left to drain.
    let entry = svc.evict("rearm").unwrap();
    assert_eq!(entry.inflight(), 0);
    assert_eq!(entry.served(), 1);
    svc.shutdown();
}

#[test]
fn swap_during_draining_evict_errors_and_leaves_key_gone() {
    // An evict and a swap racing on the same key must converge to one of
    // the two legal outcomes; with the evict strictly first, the swap
    // errors and the key stays unknown.
    let svc = ShardedSolveService::start(cfg(1)).unwrap();
    let m = gen::shallow(400, 0.4, GenSeed(123));
    svc.register("gone", &m).unwrap();
    svc.evict("gone").unwrap();
    let err = svc.swap("gone", &m).unwrap_err();
    assert!(format!("{err:#}").contains("not registered"), "{err:#}");
    assert!(svc.registry().get("gone").is_none());
    svc.shutdown();
}
