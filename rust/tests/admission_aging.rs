//! Aging-fair two-lane admission, driven through the public
//! `ShardedSolveService` API with a deterministic gated backend:
//!
//! - under `ByClass` admission with a configured aging bound, a bulk
//!   job that has outwaited the window is promoted past the latency
//!   lane and completes even while a sustained latency flood keeps the
//!   priority lane non-empty — a latency flood cannot starve bulk;
//! - with the bound disabled (the default), draining stays strictly
//!   latency-first: the same traffic shape leaves the bulk job behind
//!   every queued latency job, proving the window is what changed the
//!   ordering (and that `aged_bulk` counts exactly the promotions).
//!
//! Determinism comes from a rendezvous, not timing guesses: the first
//! latency solve blocks inside the backend until the test releases it,
//! so the queue composition and the bulk job's waited-age at the next
//! pop are both controlled exactly.

use mgd_sptrsv::coordinator::{AdmissionPolicy, ShardedServiceConfig, ShardedSolveService};
use mgd_sptrsv::matrix::gen::{self, GenSeed};
use mgd_sptrsv::matrix::triangular::solve_serial;
use mgd_sptrsv::matrix::CsrMatrix;
use mgd_sptrsv::runtime::{LevelSolver, RequestClass, SolverBackend};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

fn cfg(bulk_aging_ms: u64) -> ShardedServiceConfig {
    ShardedServiceConfig {
        shards: 1,
        workers_per_shard: 1,
        admission: AdmissionPolicy::ByClass,
        bulk_aging_ms,
        ..ShardedServiceConfig::default()
    }
}

/// Records the `b[0]` tag of every solve in arrival order, and blocks
/// the **first** solve until released — the deterministic way to build
/// a known queue shape (and a known bulk wait-age) behind a busy
/// worker before any pop-ordering decision is made.
struct GatedOrderBackend {
    order: Mutex<Vec<f32>>,
    started: mpsc::Sender<()>,
    release: Mutex<mpsc::Receiver<()>>,
    gate_open: AtomicBool,
}

impl GatedOrderBackend {
    fn new() -> (Arc<Self>, mpsc::Receiver<()>, mpsc::Sender<()>) {
        let (started_tx, started_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel();
        (
            Arc::new(Self {
                order: Mutex::new(Vec::new()),
                started: started_tx,
                release: Mutex::new(release_rx),
                gate_open: AtomicBool::new(false),
            }),
            started_rx,
            release_tx,
        )
    }

    fn order(&self) -> Vec<f32> {
        self.order.lock().unwrap().clone()
    }
}

impl SolverBackend for GatedOrderBackend {
    fn name(&self) -> &'static str {
        "gated-order"
    }

    fn solve(&self, plan: &LevelSolver, b: &[f32]) -> anyhow::Result<Vec<f32>> {
        if !self.gate_open.load(Ordering::SeqCst) {
            let _ = self.started.send(());
            // Block until the test releases the gate; stay open after
            // that so the drained queue runs through unimpeded.
            let _ = self
                .release
                .lock()
                .unwrap()
                .recv_timeout(Duration::from_secs(30));
            self.gate_open.store(true, Ordering::SeqCst);
        }
        self.order.lock().unwrap().push(b[0]);
        Ok(solve_serial(plan.matrix(), b))
    }
}

fn matrices() -> (CsrMatrix, CsrMatrix) {
    (
        gen::chain(40, GenSeed(180)),
        gen::chain(40, GenSeed(181)),
    )
}

fn tagged(n: usize, tag: f32) -> Vec<f32> {
    let mut b = vec![1.0f32; n];
    b[0] = tag;
    b
}

/// The aging bound keeps bulk alive under a sustained latency flood:
/// the bulk job outwaits the window while the worker is pinned, is
/// promoted at the very next pop — ahead of every queued latency job —
/// and its reply arrives even though latency submitters never let the
/// priority lane drain.
#[test]
fn aged_bulk_completes_under_a_sustained_latency_flood() {
    let (backend, started, release) = GatedOrderBackend::new();
    let svc = Arc::new(ShardedSolveService::start_with_backend(
        Arc::clone(&backend) as Arc<dyn SolverBackend>,
        cfg(5),
    ));
    let (probe_m, bulk_m) = matrices();
    svc.register_with_class("probe", &probe_m, RequestClass::Latency)
        .unwrap();
    svc.register("bulk", &bulk_m).unwrap();

    // Pin the single worker inside the backend on a latency job.
    let gated = svc.submit("probe", tagged(probe_m.n, 9.0)).unwrap();
    started
        .recv_timeout(Duration::from_secs(30))
        .expect("gated solve never started");

    // Build the contended queue behind it: one bulk job, then a run of
    // latency jobs that would all outrank it under strict
    // latency-first draining.
    let bulk = svc.submit("bulk", tagged(bulk_m.n, 1.0)).unwrap();
    let mut queued = Vec::new();
    for tag in [5.0f32, 6.0, 7.0] {
        queued.push(svc.submit("probe", tagged(probe_m.n, tag)).unwrap());
    }

    // Let the bulk job age well past the 5 ms window while the worker
    // is still pinned, and keep the latency lane fed for the whole
    // rest of the test — the flood the aging bound must cut through.
    std::thread::sleep(Duration::from_millis(30));
    let stop = Arc::new(AtomicBool::new(false));
    let flooder = {
        let svc = Arc::clone(&svc);
        let stop = Arc::clone(&stop);
        let n = probe_m.n;
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                svc.solve("probe", tagged(n, 8.0)).unwrap();
            }
        })
    };

    release.send(()).unwrap();
    let resp = bulk
        .wait_timeout(Duration::from_secs(10))
        .expect("bulk starved: no reply within the aging bound's reach")
        .unwrap();
    let want = solve_serial(&bulk_m, &tagged(bulk_m.n, 1.0));
    for i in 0..bulk_m.n {
        assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "bulk row {i}");
    }

    stop.store(true, Ordering::SeqCst);
    flooder.join().unwrap();
    for h in queued {
        h.wait_timeout(Duration::from_secs(10))
            .expect("queued latency reply")
            .unwrap();
    }
    gated.wait_timeout(Duration::from_secs(10)).unwrap().unwrap();

    // The gated job ran first; the aged bulk job was popped next, past
    // every already-queued latency job.
    let order = backend.order();
    assert_eq!(&order[..2], &[9.0, 1.0], "full order: {order:?}");
    let stats = svc.stats();
    assert_eq!(stats.aged_bulk, 1, "exactly one promotion, counted once");
    Arc::try_unwrap(svc).ok().expect("sole owner").shutdown();
}

/// Control: the identical queue shape with the aging bound disabled
/// drains strictly latency-first — the bulk job goes last and nothing
/// counts as aged. The promotion in the test above is therefore the
/// window's doing, not an accident of scheduling.
#[test]
fn without_the_bound_bulk_waits_behind_every_latency_job() {
    let (backend, started, release) = GatedOrderBackend::new();
    let svc = Arc::new(ShardedSolveService::start_with_backend(
        Arc::clone(&backend) as Arc<dyn SolverBackend>,
        cfg(0),
    ));
    let (probe_m, bulk_m) = matrices();
    svc.register_with_class("probe", &probe_m, RequestClass::Latency)
        .unwrap();
    svc.register("bulk", &bulk_m).unwrap();

    let gated = svc.submit("probe", tagged(probe_m.n, 9.0)).unwrap();
    started
        .recv_timeout(Duration::from_secs(30))
        .expect("gated solve never started");
    let bulk = svc.submit("bulk", tagged(bulk_m.n, 1.0)).unwrap();
    let mut queued = Vec::new();
    for tag in [5.0f32, 6.0, 7.0] {
        queued.push(svc.submit("probe", tagged(probe_m.n, tag)).unwrap());
    }
    // Same age as the promoted case — it must not matter without a
    // configured window.
    std::thread::sleep(Duration::from_millis(30));
    release.send(()).unwrap();

    bulk.wait_timeout(Duration::from_secs(10)).unwrap().unwrap();
    for h in queued {
        h.wait_timeout(Duration::from_secs(10)).unwrap().unwrap();
    }
    gated.wait_timeout(Duration::from_secs(10)).unwrap().unwrap();

    let order = backend.order();
    assert_eq!(order, vec![9.0, 5.0, 6.0, 7.0, 1.0]);
    assert_eq!(svc.stats().aged_bulk, 0);
    Arc::try_unwrap(svc).ok().expect("sole owner").shutdown();
}
