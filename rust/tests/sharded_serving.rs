//! Lifecycle and correctness tests of the sharded multi-matrix serving
//! runtime, driven through the public API only:
//!
//! - repeated service start/shutdown cycles on one shared backend reuse
//!   the persistent MGD pool (introspected worker counts stay constant —
//!   no thread leaks, no respawns);
//! - an unknown `matrix_key` gets an error reply, never a hang;
//! - concurrent requests across 3 shards × worker-thread counts
//!   {1, 2, 8} stay bitwise-identical to the serial reference.

use mgd_sptrsv::coordinator::{ShardedServiceConfig, ShardedSolveService};
use mgd_sptrsv::matrix::gen::{self, GenSeed};
use mgd_sptrsv::matrix::triangular::solve_serial;
use mgd_sptrsv::matrix::CsrMatrix;
use mgd_sptrsv::runtime::{
    BackendConfig, BackendKind, NativeBackend, NativeConfig, SchedulerKind, SolverBackend,
};
use std::sync::Arc;

fn mgd_backend(threads: usize) -> Arc<NativeBackend> {
    Arc::new(NativeBackend::new(NativeConfig {
        threads,
        scheduler: SchedulerKind::Mgd,
        ..NativeConfig::default()
    }))
}

fn sharded_cfg(shards: usize) -> ShardedServiceConfig {
    ShardedServiceConfig {
        shards,
        workers_per_shard: 2,
        batch_size: 4,
        ..ShardedServiceConfig::default()
    }
}

fn rhs(n: usize, k: usize) -> Vec<f32> {
    (0..n).map(|i| ((i + 3 * k) % 9) as f32 - 4.0).collect()
}

#[test]
fn repeated_start_shutdown_cycles_reuse_the_pool_without_thread_leaks() {
    let nb = mgd_backend(4);
    // No pool exists before the first registration warms it.
    assert_eq!(nb.mgd_pool_stats().live, 0);
    let m = gen::shallow(1200, 0.4, GenSeed(90));
    let want = solve_serial(&m, &rhs(m.n, 0));
    let mut last_sessions = 0u64;
    for cycle in 0..5 {
        let backend: Arc<dyn SolverBackend> = nb.clone();
        let svc = ShardedSolveService::start_with_backend(backend, sharded_cfg(2));
        svc.register("wide", &m).unwrap();
        for _ in 0..4 {
            let resp = svc.solve("wide", rhs(m.n, 0)).unwrap();
            for i in 0..m.n {
                assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "cycle {cycle} row {i}");
            }
        }
        svc.shutdown();
        // The pool belongs to the backend, not the service: start/stop
        // cycles must neither respawn nor leak its threads.
        let stats = nb.mgd_pool_stats();
        assert_eq!(stats.workers, 3, "cycle {cycle}: {stats:?}");
        assert_eq!(stats.live, 3, "cycle {cycle}: {stats:?}");
        assert!(
            stats.sessions > last_sessions,
            "cycle {cycle}: pool unused ({stats:?})"
        );
        last_sessions = stats.sessions;
    }
}

#[test]
fn unknown_matrix_key_gets_an_error_reply_not_a_hang() {
    let svc = ShardedSolveService::start(sharded_cfg(3)).unwrap();
    let m = gen::banded(300, 4, 0.6, GenSeed(91));
    svc.register("present", &m).unwrap();
    // Submit against a key that was never registered: the reply channel
    // must deliver a diagnostic error immediately.
    let rx = svc.submit("absent", vec![0.0; m.n]).unwrap();
    let err = rx
        .wait_timeout(std::time::Duration::from_secs(30))
        .expect("reply must arrive, not hang")
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown matrix key"), "{msg}");
    assert!(msg.contains("present"), "should list registered keys: {msg}");
    // The service still serves the registered matrix afterwards.
    assert!(svc.solve("present", vec![1.0; m.n]).is_ok());
    svc.shutdown();
}

#[test]
fn concurrent_requests_across_three_shards_stay_bitwise_serial() {
    // Three matrices with different DAG shapes on three shards; the MGD
    // scheduler's contract is bitwise equality with solve_serial at any
    // thread count, so every reply is checked exactly.
    let mats: Vec<(&str, CsrMatrix)> = vec![
        ("wide", gen::shallow(900, 0.4, GenSeed(92))),
        ("band", gen::banded(700, 3, 0.9, GenSeed(93))),
        ("deep", gen::circuit(800, 4, 0.8, GenSeed(94))),
    ];
    for threads in [1usize, 2, 8] {
        let backend: Arc<dyn SolverBackend> = mgd_backend(threads);
        let svc = Arc::new(ShardedSolveService::start_with_backend(
            backend,
            sharded_cfg(3),
        ));
        for (key, m) in &mats {
            let entry = svc.register(key, m).unwrap();
            assert!(entry.shard() < 3);
        }
        // 4 submitter threads × 9 requests, round-robin over the keys.
        let mut submitters = Vec::new();
        for t in 0..4usize {
            let svc = Arc::clone(&svc);
            let mats: Vec<(String, usize)> =
                mats.iter().map(|(k, m)| (k.to_string(), m.n)).collect();
            submitters.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                for r in 0..9usize {
                    let (key, n) = &mats[(t + r) % mats.len()];
                    let b = rhs(*n, t * 9 + r);
                    let rx = svc.submit(key, b.clone()).unwrap();
                    got.push((key.clone(), b, rx));
                }
                got.into_iter()
                    .map(|(key, b, rx)| (key, b, rx.wait().unwrap()))
                    .collect::<Vec<_>>()
            }));
        }
        for s in submitters {
            for (key, b, resp) in s.join().unwrap() {
                let m = &mats.iter().find(|(k, _)| *k == key).unwrap().1;
                let want = solve_serial(m, &b);
                for i in 0..m.n {
                    assert_eq!(
                        resp.x[i].to_bits(),
                        want[i].to_bits(),
                        "threads={threads} key={key} row {i}"
                    );
                }
            }
        }
        let agg = svc.stats();
        assert_eq!(agg.served, 36, "threads={threads}: {agg:?}");
        assert_eq!(agg.errors, 0, "threads={threads}: {agg:?}");
        // Every shard owns one matrix and saw 12 of the 36 requests.
        for s in svc.shard_stats() {
            assert_eq!(s.served, 12, "threads={threads}: {s:?}");
        }
        let registry = svc.registry();
        assert_eq!(registry.len(), 3);
        let total: u64 = registry
            .keys()
            .iter()
            .map(|k| registry.get(k).unwrap().served())
            .sum();
        assert_eq!(total, 36);
        Arc::try_unwrap(svc).ok().expect("sole owner").shutdown();
    }
}

/// Regression for the batch-starvation bug: a worker used to greedily
/// drain up to `batch_size` jobs even when the backend could not batch
/// them (no multi-RHS), serializing the whole burst behind itself while
/// sibling workers idled. Now an unbatchable burst spreads one job per
/// worker: this backend's solves rendezvous — each blocks until **two**
/// solves are simultaneously inside the backend — so the test can only
/// pass if two shard workers really overlap on a 2-job burst.
#[test]
fn two_workers_overlap_on_an_unbatchable_two_job_burst() {
    use mgd_sptrsv::runtime::LevelSolver;
    use std::sync::atomic::{AtomicUsize, Ordering};

    struct RendezvousBackend {
        arrived: AtomicUsize,
    }

    impl SolverBackend for RendezvousBackend {
        fn name(&self) -> &'static str {
            "rendezvous"
        }

        // No supports_multi_rhs override: the backend cannot batch, so a
        // correct worker must not drain more than one of these jobs.
        fn solve(&self, plan: &LevelSolver, b: &[f32]) -> anyhow::Result<Vec<f32>> {
            self.arrived.fetch_add(1, Ordering::SeqCst);
            let mut spins = 0u64;
            while self.arrived.load(Ordering::SeqCst) < 2 {
                std::thread::yield_now();
                spins += 1;
                assert!(
                    spins < 500_000_000,
                    "second worker never arrived: greedy drain serialized the burst"
                );
            }
            Ok(solve_serial(plan.matrix(), b))
        }
    }

    let svc = ShardedSolveService::start_with_backend(
        Arc::new(RendezvousBackend {
            arrived: AtomicUsize::new(0),
        }),
        ShardedServiceConfig {
            shards: 1,
            workers_per_shard: 2,
            // A batch window larger than the burst: the old greedy drain
            // would pull both jobs into one worker and deadlock the
            // rendezvous; the fixed drain leaves job 2 for worker 2.
            batch_size: 4,
            ..ShardedServiceConfig::default()
        },
    );
    let m = gen::chain(80, GenSeed(97));
    svc.register("burst", &m).unwrap();
    let b1 = rhs(m.n, 1);
    let b2 = rhs(m.n, 2);
    let h1 = svc.submit("burst", b1.clone()).unwrap();
    let h2 = svc.submit("burst", b2.clone()).unwrap();
    for (h, b) in [(h1, b1), (h2, b2)] {
        let resp = h
            .wait_timeout(std::time::Duration::from_secs(60))
            .expect("burst reply must arrive")
            .unwrap();
        let want = solve_serial(&m, &b);
        for i in 0..m.n {
            assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "row {i}");
        }
    }
    let stats = svc.stats();
    assert_eq!(stats.served, 2);
    assert_eq!(stats.batched_rounds, 2, "one dispatch per worker: {stats:?}");
    svc.shutdown();
}

#[test]
fn per_shard_backends_serve_correctly() {
    let cfg = ShardedServiceConfig {
        backend: BackendConfig {
            kind: BackendKind::Native,
            native: NativeConfig {
                threads: 2,
                scheduler: SchedulerKind::Mgd,
                ..NativeConfig::default()
            },
            ..BackendConfig::default()
        },
        backend_per_shard: true,
        ..sharded_cfg(2)
    };
    let svc = ShardedSolveService::start(cfg).unwrap();
    let ma = gen::shallow(600, 0.4, GenSeed(95));
    let mb = gen::chain(400, GenSeed(96));
    svc.register("a", &ma).unwrap();
    svc.register("b", &mb).unwrap();
    for k in 0..6 {
        let (key, m) = if k % 2 == 0 { ("a", &ma) } else { ("b", &mb) };
        let b = rhs(m.n, k);
        let resp = svc.solve(key, b.clone()).unwrap();
        let want = solve_serial(m, &b);
        for i in 0..m.n {
            assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "k={k} row {i}");
        }
    }
    assert_eq!(svc.stats().served, 6);
    svc.shutdown();
}
