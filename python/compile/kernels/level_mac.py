"""L1 — the Pallas level-MAC kernel.

The accelerator's PE computes ``psum += L_ij * x_j`` streams followed by
``x_i = (b_i - psum) * L_ii^-1`` (paper eq. 2). On a TPU-shaped target the
numeric hot loop of a *level* (a set of independent rows) is a padded
segmented multiply-accumulate: rows are packed into a ``(B, E)`` tile
(``E`` = padded edge budget per row, zero-filled), staged HBM->VMEM with a
``BlockSpec`` over the row dimension, and reduced on the VPU.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's ASIC
feeds PEs from stream FIFOs; the TPU analog is VMEM tiling — the
``BlockSpec`` below expresses the HBM->VMEM schedule the ASIC did with
FIFOs. The reduction is deliberately VPU-shaped, not MXU-shaped: every
``L`` value is used exactly once, so a systolic matmul would waste the MXU.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO with identical numerics
(see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per VMEM block. 8 f32 sublanes x 128 lanes is the natural TPU tile;
# 32 rows x E<=64 edges keeps the block well under VMEM budgets
# (32*64*4B*2 buffers = 16 KiB << 16 MiB VMEM).
DEFAULT_BLOCK_ROWS = 32


def _kernel(vals_ref, xg_ref, b_ref, dinv_ref, out_ref):
    """One (TB, E) block: out = (b - sum(vals * xg, axis=1)) * dinv."""
    acc = jnp.sum(vals_ref[...] * xg_ref[...], axis=1)
    out_ref[...] = (b_ref[...] - acc) * dinv_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def level_mac(vals, xg, b, dinv, *, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Solve one padded level.

    Args:
      vals: ``(B, E)`` f32 — off-diagonal values, zero-padded per row.
      xg:   ``(B, E)`` f32 — gathered solutions ``x[colidx]``, zero-padded.
      b:    ``(B,)``  f32 — right-hand sides of the level's rows.
      dinv: ``(B,)``  f32 — reciprocal diagonals.
      block_rows: VMEM block height (must divide B).

    Returns:
      ``(B,)`` f32 — the level's solutions.
    """
    bsz, esz = vals.shape
    assert xg.shape == (bsz, esz) and b.shape == (bsz,) and dinv.shape == (bsz,)
    tb = min(block_rows, bsz)
    assert bsz % tb == 0, f"block_rows {tb} must divide B {bsz}"
    grid = (bsz // tb,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, esz), lambda i: (i, 0)),
            pl.BlockSpec((tb, esz), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(vals, xg, b, dinv)


def vmem_footprint_bytes(block_rows: int, e: int) -> int:
    """Estimated VMEM bytes for one block (2 operand tiles + 3 vectors),
    double-buffered. Used by the DESIGN.md roofline discussion."""
    tile = block_rows * e * 4
    vecs = 3 * block_rows * 4
    return 2 * (2 * tile + vecs)
