"""Pallas kernels (L1) and their pure-jnp oracles."""

from .level_mac import level_mac, vmem_footprint_bytes  # noqa: F401
from .ref import level_mac_ref, solve_levels_ref  # noqa: F401
