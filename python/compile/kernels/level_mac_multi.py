"""L1 — multi-RHS level-MAC Pallas kernel.

The paper's motivating applications solve the same factor against a stream
of right-hand sides (transient simulation, iterative refinement). The
scalar kernel is dispatch-bound on thin levels (EXPERIMENTS.md §Perf:
~100 us/level through PJRT), so this variant processes ``R`` RHS per
dispatch: the ``vals`` tile (matrix structure) is shared, the gathered
``xg`` and ``b`` carry an RHS axis, amortizing both dispatch and the
HBM->VMEM staging of ``vals`` across the batch — the same reuse argument
as the accelerator's stream memory.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(vals_ref, xg_ref, b_ref, dinv_ref, out_ref):
    """One (TB, E) block against R RHS.

    Shapes inside the block: vals (TB, E); xg (R, TB, E); b (R, TB);
    dinv (TB,); out (R, TB).
    """
    acc = jnp.sum(vals_ref[...][None, :, :] * xg_ref[...], axis=2)
    out_ref[...] = (b_ref[...] - acc) * dinv_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def level_mac_multi(vals, xg, b, dinv, *, block_rows: int = 32):
    """Solve one padded level for a batch of RHS.

    Args:
      vals: ``(B, E)`` f32 — shared off-diagonal values, zero-padded.
      xg:   ``(R, B, E)`` f32 — per-RHS gathered solutions.
      b:    ``(R, B)`` f32 — per-RHS right-hand sides.
      dinv: ``(B,)`` f32 — shared reciprocal diagonals.

    Returns:
      ``(R, B)`` f32.
    """
    bsz, esz = vals.shape
    r = xg.shape[0]
    assert xg.shape == (r, bsz, esz) and b.shape == (r, bsz) and dinv.shape == (bsz,)
    tb = min(block_rows, bsz)
    assert bsz % tb == 0
    grid = (bsz // tb,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, esz), lambda i: (i, 0)),
            pl.BlockSpec((r, tb, esz), lambda i: (0, i, 0)),
            pl.BlockSpec((r, tb), lambda i: (0, i)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((r, tb), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r, bsz), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(vals, xg, b, dinv)


def level_mac_multi_ref(vals, xg, b, dinv):
    """Pure-jnp oracle."""
    acc = jnp.sum(vals[None, :, :] * xg, axis=2)
    return (b - acc) * dinv[None, :]
