"""Pure-jnp oracle for the level-MAC kernel (no Pallas)."""

import jax.numpy as jnp


def level_mac_ref(vals, xg, b, dinv):
    """Reference: out = (b - sum(vals * xg, axis=1)) * dinv."""
    return (b - jnp.sum(vals * xg, axis=1)) * dinv


def solve_levels_ref(rowptr, colidx, values, b):
    """Full level-scheduled SpTRSV in plain numpy-style python — the golden
    numeric model for the L2 tests. Diagonal-last CSR convention."""
    import numpy as np

    n = len(rowptr) - 1
    x = np.zeros(n, dtype=np.float32)
    for i in range(n):
        lo, hi = rowptr[i], rowptr[i + 1] - 1
        s = np.float32(0.0)
        for k in range(lo, hi):
            s += np.float32(values[k]) * x[colidx[k]]
        x[i] = (np.float32(b[i]) - s) / np.float32(values[hi])
    return x
