"""AOT export: lower the L2 ``level_step`` to HLO *text* artifacts.

HLO text, NOT serialized protos: jax >= 0.5 emits HloModuleProtos with
64-bit instruction ids that the rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and gen_hlo.py).

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``

One executable is emitted per (batch, edge-budget) variant; the rust
runtime picks the variant per level. ``manifest.txt`` lists them.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.level_mac_multi import level_mac_multi
from .model import level_step

# (batch rows, edge budget) variants compiled ahead of time. The small
# variant serves narrow levels with low padding waste; the large one
# amortizes dispatch on wide levels.
VARIANTS = [(64, 16), (256, 32)]

# (rhs batch, rows, edges) multi-RHS variants (EXPERIMENTS.md §Perf:
# amortize PJRT dispatch across a transient simulation's RHS stream).
MULTI_VARIANTS = [(8, 64, 16)]


def multi_step(vals, xg, b, dinv):
    """The exported multi-RHS computation (1-tuple, like level_step)."""
    return (level_mac_multi(vals, xg, b, dinv),)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(batch: int, edges: int) -> str:
    mat = jax.ShapeDtypeStruct((batch, edges), jnp.float32)
    vec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    lowered = jax.jit(level_step).lower(mat, mat, vec, vec)
    return to_hlo_text(lowered)


def lower_multi_variant(rhs: int, batch: int, edges: int) -> str:
    vals = jax.ShapeDtypeStruct((batch, edges), jnp.float32)
    xg = jax.ShapeDtypeStruct((rhs, batch, edges), jnp.float32)
    b = jax.ShapeDtypeStruct((rhs, batch), jnp.float32)
    dinv = jax.ShapeDtypeStruct((batch,), jnp.float32)
    lowered = jax.jit(multi_step).lower(vals, xg, b, dinv)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for batch, edges in VARIANTS:
        text = lower_variant(batch, edges)
        name = f"level_mac_{batch}x{edges}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name} {batch} {edges}")
        print(f"wrote {len(text)} chars to {path}")
    multi_manifest = []
    for rhs, batch, edges in MULTI_VARIANTS:
        text = lower_multi_variant(rhs, batch, edges)
        name = f"level_mac_multi_{rhs}x{batch}x{edges}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        multi_manifest.append(f"{name} {rhs} {batch} {edges}")
        print(f"wrote {len(text)} chars to {path}")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    with open(os.path.join(args.out_dir, "manifest_multi.txt"), "w") as f:
        f.write("\n".join(multi_manifest) + "\n")
    print(f"manifest: {len(manifest)} scalar + {len(multi_manifest)} multi variants")


if __name__ == "__main__":
    main()
