"""L2 — the JAX compute graph of level-scheduled SpTRSV.

The model is the numeric counterpart of the rust accelerator: the matrix
structure is preprocessed (levels, per-row gather indices, padding) and the
per-level compute is the L1 Pallas kernel. The exported artifact is the
fixed-shape ``level_step`` below; the rust runtime marshals each level into
the padded ``(B, E)`` tile and executes the compiled executable per level
(python never runs on the request path).
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import level_mac


def level_step(vals, xg, b, dinv):
    """One padded-level solve (the exported computation).

    All arguments are fixed-shape; rows beyond the level's real size must
    be padded with ``vals = 0``, ``b = 0``, ``dinv = 1`` so they produce 0.
    """
    return (level_mac(vals, xg, b, dinv),)


def plan_levels(rowptr, colidx, n):
    """Preprocess a diagonal-last CSR structure into a level plan.

    Returns a list of levels; each level is ``(rows, cols)`` where ``rows``
    is the array of row ids and ``cols[r, e]`` the gather indices padded
    with 0 (gathering ``x[0]`` against a 0 value is harmless).
    """
    level_of = np.zeros(n, dtype=np.int64)
    for i in range(n):
        lo, hi = rowptr[i], rowptr[i + 1] - 1
        lv = 0
        for k in range(lo, hi):
            lv = max(lv, level_of[colidx[k]] + 1)
        level_of[i] = lv
    plans = []
    for lv in range(level_of.max() + 1 if n else 0):
        rows = np.nonzero(level_of == lv)[0]
        plans.append(rows)
    return level_of, plans


def solve(rowptr, colidx, values, b, batch=64, edge_budget=16):
    """Full solve by repeated ``level_step`` calls (the python-side mirror
    of what the rust runtime does; used for L2 tests).

    Rows whose in-degree exceeds ``edge_budget`` fall back to a split
    accumulation over several kernel invocations.
    """
    n = len(rowptr) - 1
    rowptr = np.asarray(rowptr)
    colidx = np.asarray(colidx)
    values = np.asarray(values, dtype=np.float32)
    b = np.asarray(b, dtype=np.float32)
    x = np.zeros(n, dtype=np.float32)
    _, plans = plan_levels(rowptr, colidx, n)
    for rows in plans:
        for start in range(0, len(rows), batch):
            chunk = rows[start : start + batch]
            bsz = batch
            vals = np.zeros((bsz, edge_budget), dtype=np.float32)
            xg = np.zeros((bsz, edge_budget), dtype=np.float32)
            bb = np.zeros(bsz, dtype=np.float32)
            dinv = np.ones(bsz, dtype=np.float32)
            # Partial sums for rows with more edges than the budget.
            carry = np.zeros(bsz, dtype=np.float32)
            for r, i in enumerate(chunk):
                lo, hi = rowptr[i], rowptr[i + 1] - 1
                k = hi - lo
                cols = colidx[lo:hi]
                vs = values[lo:hi]
                if k > edge_budget:
                    # Fold the overflow serially into the carry.
                    extra = k - edge_budget
                    carry[r] = np.dot(
                        vs[edge_budget:], x[cols[edge_budget:]]
                    ).astype(np.float32)
                    k = edge_budget
                vals[r, :k] = vs[:k]
                xg[r, :k] = x[cols[:k]]
                bb[r] = b[i] - carry[r]
                dinv[r] = 1.0 / values[hi]
            (out,) = level_step(
                jnp.asarray(vals), jnp.asarray(xg), jnp.asarray(bb), jnp.asarray(dinv)
            )
            out = np.asarray(out)
            for r, i in enumerate(chunk):
                x[i] = out[r]
    return x
