"""Multi-RHS kernel tests (pallas vs jnp oracle + AOT lowering)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot
from compile.kernels.level_mac_multi import level_mac_multi, level_mac_multi_ref


def _rand(shape, seed, lo=-2.0, hi=2.0):
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, size=shape).astype(np.float32)


@pytest.mark.parametrize("r,bsz,esz", [(2, 32, 8), (8, 64, 16), (4, 32, 1)])
def test_matches_ref(r, bsz, esz):
    vals = _rand((bsz, esz), 1)
    xg = _rand((r, bsz, esz), 2)
    b = _rand((r, bsz), 3)
    dinv = _rand((bsz,), 4, lo=0.5, hi=1.5)
    got = np.asarray(level_mac_multi(vals, xg, b, dinv))
    want = np.asarray(level_mac_multi_ref(vals, xg, b, dinv))
    # Reduction order differs between the blocked kernel and the oracle.
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_multi_equals_scalar_rows():
    # Each RHS slice must equal an independent scalar-kernel solve.
    from compile.kernels import level_mac

    r, bsz, esz = 8, 64, 16
    vals = _rand((bsz, esz), 5)
    xg = _rand((r, bsz, esz), 6)
    b = _rand((r, bsz), 7)
    dinv = _rand((bsz,), 8, lo=0.5, hi=1.5)
    multi = np.asarray(level_mac_multi(vals, xg, b, dinv))
    for k in range(r):
        single = np.asarray(level_mac(vals, xg[k], b[k], dinv))
        np.testing.assert_allclose(multi[k], single, rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    r=st.sampled_from([1, 2, 8]),
    esz=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_sweep(r, esz, seed):
    bsz = 32
    vals = _rand((bsz, esz), seed)
    xg = _rand((r, bsz, esz), seed + 1)
    b = _rand((r, bsz), seed + 2)
    dinv = _rand((bsz,), seed + 3, lo=0.25, hi=4.0)
    got = np.asarray(level_mac_multi(vals, xg, b, dinv, block_rows=8))
    want = np.asarray(level_mac_multi_ref(vals, xg, b, dinv))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_aot_multi_lowering():
    text = aot.lower_multi_variant(8, 64, 16)
    assert "HloModule" in text and "ROOT" in text
    assert "f32[8,64,16]" in text
