"""L2 model tests: padded level-scheduled solve vs the serial oracle, and
the AOT lowering path."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import solve_levels_ref


def random_lower_csr(n, avg_deg, seed):
    """Diagonal-last CSR of a random well-conditioned lower matrix."""
    rng = np.random.default_rng(seed)
    rowptr = [0]
    colidx, values = [], []
    for i in range(n):
        deg = min(i, rng.poisson(avg_deg))
        cols = sorted(rng.choice(i, size=deg, replace=False)) if deg else []
        mag = 0.0
        for c in cols:
            v = -rng.uniform(0.1, 1.0)
            colidx.append(int(c))
            values.append(np.float32(v))
            mag += abs(v)
        colidx.append(i)
        values.append(np.float32(mag + rng.uniform(1.0, 2.0)))
        rowptr.append(len(colidx))
    return np.array(rowptr), np.array(colidx), np.array(values, np.float32)


@pytest.mark.parametrize("n,deg,seed", [(50, 2, 0), (200, 4, 1), (400, 6, 2)])
def test_solve_matches_serial(n, deg, seed):
    rowptr, colidx, values = random_lower_csr(n, deg, seed)
    b = np.linspace(-3, 3, n).astype(np.float32)
    want = solve_levels_ref(rowptr, colidx, values, b)
    got = model.solve(rowptr, colidx, values, b, batch=64, edge_budget=16)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_solve_handles_heavy_rows():
    # Rows above the edge budget exercise the carry fallback.
    rowptr, colidx, values = random_lower_csr(300, 24, 3)
    b = np.ones(300, np.float32)
    want = solve_levels_ref(rowptr, colidx, values, b)
    got = model.solve(rowptr, colidx, values, b, batch=64, edge_budget=16)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=5, max_value=120),
    deg=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_hypothesis_solve_sweep(n, deg, seed):
    rowptr, colidx, values = random_lower_csr(n, deg, seed)
    b = np.full(n, 0.5, np.float32)
    want = solve_levels_ref(rowptr, colidx, values, b)
    got = model.solve(rowptr, colidx, values, b, batch=32, edge_budget=8)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_plan_levels_respects_deps():
    rowptr, colidx, values = random_lower_csr(150, 4, 5)
    level_of, plans = model.plan_levels(rowptr, colidx, 150)
    for i in range(150):
        for k in range(rowptr[i], rowptr[i + 1] - 1):
            assert level_of[colidx[k]] < level_of[i]
    assert sum(len(p) for p in plans) == 150


def test_aot_lowering_emits_hlo_text():
    text = aot.lower_variant(64, 16)
    assert "HloModule" in text
    assert "f32[64,16]" in text
    # The rust loader needs the entry computation; smoke-check ROOT exists.
    assert "ROOT" in text


def test_aot_variants_distinct():
    a = aot.lower_variant(64, 16)
    c = aot.lower_variant(256, 32)
    assert "f32[256,32]" in c and "f32[256,32]" not in a
