"""L1 kernel tests: Pallas level-MAC vs the pure-jnp oracle, with
hypothesis sweeps over shapes and values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import level_mac, level_mac_ref, vmem_footprint_bytes


def _rand(shape, seed, lo=-2.0, hi=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


@pytest.mark.parametrize("bsz,esz", [(32, 8), (64, 16), (256, 32), (32, 1)])
def test_matches_ref(bsz, esz):
    vals = _rand((bsz, esz), 1)
    xg = _rand((bsz, esz), 2)
    b = _rand((bsz,), 3)
    dinv = _rand((bsz,), 4, lo=0.5, hi=1.5)
    got = level_mac(vals, xg, b, dinv)
    want = level_mac_ref(vals, xg, b, dinv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_zero_padding_is_identity():
    # Padded rows (vals=0, b=0, dinv=1) must produce exactly 0.
    bsz, esz = 64, 16
    vals = jnp.zeros((bsz, esz), jnp.float32)
    xg = _rand((bsz, esz), 5)  # garbage gathers are harmless against 0
    b = jnp.zeros((bsz,), jnp.float32)
    dinv = jnp.ones((bsz,), jnp.float32)
    out = np.asarray(level_mac(vals, xg, b, dinv))
    np.testing.assert_array_equal(out, np.zeros(bsz, np.float32))


def test_block_rows_variants_agree():
    bsz, esz = 128, 16
    vals, xg = _rand((bsz, esz), 6), _rand((bsz, esz), 7)
    b, dinv = _rand((bsz,), 8), _rand((bsz,), 9, lo=0.5, hi=1.5)
    a = np.asarray(level_mac(vals, xg, b, dinv, block_rows=32))
    c = np.asarray(level_mac(vals, xg, b, dinv, block_rows=128))
    np.testing.assert_allclose(a, c, rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    bsz=st.sampled_from([8, 32, 64]),
    esz=st.integers(min_value=1, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_shape_sweep(bsz, esz, seed):
    vals = _rand((bsz, esz), seed)
    xg = _rand((bsz, esz), seed + 1)
    b = _rand((bsz,), seed + 2)
    dinv = _rand((bsz,), seed + 3, lo=0.25, hi=4.0)
    got = np.asarray(level_mac(vals, xg, b, dinv, block_rows=8))
    want = np.asarray(level_mac_ref(vals, xg, b, dinv))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    scale=st.floats(min_value=1e-3, max_value=1e3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_value_scaling(scale, seed):
    # Numeric robustness across magnitudes.
    bsz, esz = 32, 8
    vals = _rand((bsz, esz), seed) * scale
    xg = _rand((bsz, esz), seed + 1)
    b = _rand((bsz,), seed + 2) * scale
    dinv = _rand((bsz,), seed + 3, lo=0.5, hi=1.5) / scale
    got = np.asarray(level_mac(vals, xg, b, dinv, block_rows=8))
    want = np.asarray(level_mac_ref(vals, xg, b, dinv))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale)


def test_vmem_footprint_under_budget():
    # 16 MiB VMEM on current TPUs; our default block must be far below.
    assert vmem_footprint_bytes(32, 64) < 64 * 1024
    assert vmem_footprint_bytes(256, 32) < 256 * 1024


def test_jit_cache_stable():
    # Two calls with the same shapes must not retrace (guard for the AOT
    # path: one executable per variant).
    bsz, esz = 64, 16
    vals, xg = _rand((bsz, esz), 10), _rand((bsz, esz), 11)
    b, dinv = _rand((bsz,), 12), _rand((bsz,), 13, lo=0.5, hi=1.5)
    f = jax.jit(lambda *a: level_mac(*a))
    _ = f(vals, xg, b, dinv)
    n0 = f._cache_size()
    _ = f(vals, xg, b, dinv)
    assert f._cache_size() == n0
