//! Quickstart: compile the paper's Fig. 1 matrix, simulate it cycle by
//! cycle, verify the numerics, and print the schedule statistics.
//!
//! Run: `cargo run --release --example quickstart`

use mgd_sptrsv::compiler::{compile, CompilerConfig};
use mgd_sptrsv::matrix::triangular::solve_serial;
use mgd_sptrsv::matrix::CsrMatrix;
use mgd_sptrsv::sim::Accelerator;

fn main() -> anyhow::Result<()> {
    // The 10-node example of the paper's Fig. 1.
    let m = CsrMatrix::paper_fig1();
    let cfg = CompilerConfig::default();
    let prog = compile(&m, &cfg)?;
    println!(
        "compiled fig. 1 matrix: n={} nnz={} → {} cycles predicted, {} VLIW words, paper word length {} bits",
        prog.n,
        prog.nnz,
        prog.predicted.cycles,
        prog.instr_words(),
        cfg.arch.paper_word_bits(),
    );

    let b = vec![1.0f32; m.n];
    let mut acc = Accelerator::new(cfg.arch);
    let run = acc.run(&prog, &b)?;
    run.stats.verify_against(&prog.predicted)?;

    let x_ref = solve_serial(&m, &b);
    for (i, (&got, &want)) in run.x.iter().zip(&x_ref).enumerate() {
        assert!((got - want).abs() < 1e-4, "row {i}");
    }
    println!(
        "simulated {} cycles — numerics match the serial reference",
        run.stats.cycles
    );
    println!(
        "x = {:?}",
        run.x.iter().map(|v| *v as i32).collect::<Vec<_>>()
    );
    println!(
        "instruction mix: {} exec, {} bnop, {} pnop, {} dnop, {} lnop; utilization {:.1}%",
        run.stats.exec,
        run.stats.bnop,
        run.stats.pnop,
        run.stats.dnop,
        run.stats.lnop,
        100.0 * run.stats.utilization(cfg.arch.num_cus()),
    );
    Ok(())
}
