//! Sharded multi-matrix serving end to end: start a 2-shard service,
//! register two triangular factors by key, stream interleaved requests
//! against both, hot-swap one factor live, evict the other, and read
//! the per-shard/aggregate serving stats.
//!
//! This is the registry API walkthrough referenced from ARCHITECTURE.md.
//!
//! Run: `cargo run --release --example serve_two_matrices`

use mgd_sptrsv::coordinator::{Admission, ShardedServiceConfig, ShardedSolveService};
use mgd_sptrsv::matrix::gen::{self, GenSeed};
use mgd_sptrsv::matrix::triangular::solve_serial;
use mgd_sptrsv::runtime::{BackendConfig, BackendKind, NativeConfig, RequestClass, SchedulerKind};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // One service, two shards, sharing one native backend — and therefore
    // one persistent MGD worker pool — across both shards. The mgd
    // scheduler is pinned so every reply below can be checked *bitwise*
    // against the serial reference (the level scheduler's contract is
    // only a residual tolerance).
    let svc = ShardedSolveService::start(ShardedServiceConfig {
        shards: 2,
        workers_per_shard: 2,
        backend: BackendConfig {
            kind: BackendKind::Native,
            native: NativeConfig {
                scheduler: SchedulerKind::Mgd,
                ..NativeConfig::default()
            },
            ..BackendConfig::default()
        },
        ..ShardedServiceConfig::default()
    })?;

    // Registration is the amortization boundary: each matrix is compiled,
    // simulated (cost model + double-entry check) and planned exactly
    // once, then pinned to a shard round-robin.
    let grid = gen::shallow(3000, 0.4, GenSeed(1));
    let band = gen::banded(2500, 3, 0.9, GenSeed(2));
    let e0 = svc.register("power_grid", &grid)?;
    let e1 = svc.register("transient_band", &band)?;
    println!(
        "registered power_grid on shard {} ({} cycles/solve predicted), \
         transient_band on shard {} ({} cycles/solve predicted)",
        e0.shard(),
        e0.metrics().cycles,
        e1.shard(),
        e1.metrics().cycles,
    );

    // Interleaved request stream: submit everything, then await replies.
    // Requests route to the shard owning their matrix key; same-matrix
    // requests drained together ride the backend's multi-RHS path.
    let mut pending = Vec::new();
    for k in 0..32usize {
        let (key, m) = if k % 2 == 0 {
            ("power_grid", &grid)
        } else {
            ("transient_band", &band)
        };
        let b: Vec<f32> = (0..m.n).map(|i| ((i + k) % 9) as f32 - 4.0).collect();
        pending.push((key, b.clone(), svc.submit(key, b)?));
    }
    for (key, b, rx) in pending {
        let resp = rx.wait()?;
        let m = if key == "power_grid" { &grid } else { &band };
        // The native MGD scheduler's contract: bitwise-identical to the
        // serial reference.
        let want = solve_serial(m, &b);
        for i in 0..m.n {
            assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "{key} row {i}");
        }
    }

    // Unknown keys are answered with an error reply, never a hang.
    let err = svc.solve("no_such_matrix", vec![0.0; 8]).unwrap_err();
    println!("unknown key rejected as expected: {err:#}");

    // Live hot swap: replace the power-grid factor (say, after a
    // re-factorization) without stopping traffic. The new entry is
    // compiled, simulated and planned off the hot path, the owning
    // shard's backend is warmed, and only then is the entry published
    // atomically — requests mid-swap are served by whichever
    // fully-formed entry they resolve.
    let grid2 = gen::shallow(3000, 0.4, GenSeed(3));
    let swapped = svc.swap("power_grid", &grid2)?;
    println!(
        "hot-swapped power_grid (still shard {}, {} lifetime requests on the key)",
        swapped.shard(),
        swapped.served(),
    );
    let b: Vec<f32> = (0..grid2.n).map(|i| (i % 5) as f32 - 2.0).collect();
    let resp = svc.solve("power_grid", b.clone())?;
    let want = solve_serial(&grid2, &b);
    for i in 0..grid2.n {
        assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "post-swap row {i}");
    }

    // Admission-aware submission: `try_route` never parks under a shed
    // policy and reports the verdict; an admitted request hands back a
    // `SolveHandle`, whose `wait_timeout` finally gives callers a
    // deadline (an expired deadline leaves the request in flight — the
    // reply can still be awaited later). The `Latency` class jumps any
    // bulk backlog on the shard queue and may lease the pool's reserved
    // workers (none are reserved in this default config).
    let b: Vec<f32> = (0..grid2.n).map(|i| (i % 3) as f32).collect();
    match svc.try_route("power_grid", b.clone(), Some(RequestClass::Latency))? {
        Admission::Admitted(handle) => {
            let resp = handle
                .wait_timeout(Duration::from_secs(30))
                .expect("a 30s deadline is generous for this solve")?;
            let want = solve_serial(&grid2, &b);
            for i in 0..grid2.n {
                assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "latency row {i}");
            }
            println!("latency-class request served under a deadline");
        }
        Admission::Shed(reason) => println!("request shed: {reason}"),
    }

    // Eviction: retire a cold matrix. The call drains any in-flight
    // requests for the key, then the plan drops with its last reference;
    // the key is immediately unknown to new submits and free to reuse.
    let evicted = svc.evict("transient_band")?;
    println!(
        "evicted transient_band after {} requests; registry now holds {:?}",
        evicted.served(),
        svc.registry().keys(),
    );
    assert!(svc.solve("transient_band", vec![0.0; 8]).is_err());

    for s in svc.shard_stats() {
        println!(
            "shard {}: {} served, {} errors, {} dispatch rounds, {:.3} ms in backend",
            s.shard,
            s.served,
            s.errors,
            s.batched_rounds,
            s.solve_seconds * 1e3,
        );
    }
    let agg = svc.stats();
    println!(
        "aggregate: {} served across {} shards on the {} backend \
         (power_grid lifetime={}, evicted transient_band={}, \
         peak pool-session concurrency={})",
        agg.served,
        agg.shards,
        svc.backend_name(),
        svc.registry().get("power_grid").unwrap().served(),
        evicted.served(),
        agg.peak_concurrency,
    );
    svc.shutdown();
    Ok(())
}
