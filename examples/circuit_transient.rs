//! **End-to-end driver** (EXPERIMENTS.md §E2E): fixed-step transient
//! simulation of a linear circuit — the paper's §I motivating application.
//!
//! The same triangular factor is solved against a stream of time-step RHS
//! vectors through the full stack:
//!
//! 1. L3 compiles the matrix into an accelerator program, runs the
//!    cycle-accurate simulator once, and verifies the double-entry check;
//! 2. the solve service batches 500 time-step requests over worker threads,
//!    then re-streams the same sequence through a pipelined `SolveSession`
//!    with a bounded in-flight window;
//! 3. every numeric solve runs on the selected `SolverBackend` — the
//!    native parallel level executor by default, or the AOT-compiled
//!    JAX/Pallas kernels through PJRT when built with `--features pjrt`
//!    and `make artifacts` has produced the HLO modules;
//! 4. every 50th solution is re-verified against the serial reference.
//!
//! Run: `cargo run --release --example circuit_transient`

use mgd_sptrsv::coordinator::{ServiceConfig, SolveService};
use mgd_sptrsv::matrix::gen::{self, GenSeed};
use mgd_sptrsv::matrix::triangular::solve_serial;
use std::time::Instant;

const STEPS: usize = 500;

fn main() -> anyhow::Result<()> {
    // A circuit-like lower factor (add20-scale).
    let m = gen::circuit(2395, 3, 0.8, GenSeed(42));
    println!(
        "transient sim: n={} nnz={} ({} flops/solve), {STEPS} time steps",
        m.n,
        m.nnz(),
        m.binary_nodes()
    );
    let cfg = ServiceConfig::default();
    let t0 = Instant::now();
    let svc = SolveService::start(&m, cfg)?;
    println!(
        "service up in {:.2}s on the {} backend: compile {:.1} ms, accel {} cycles/solve \
         ({:.2} GOPS, {:.1}% util, {:.1} GOPS/W)",
        t0.elapsed().as_secs_f64(),
        svc.backend_name(),
        svc.program.compile.compile_seconds * 1e3,
        svc.metrics.cycles,
        svc.metrics.gops,
        100.0 * svc.metrics.utilization,
        svc.metrics.gops_per_w,
    );

    // Drive the transient loop: b(t) = dc + sin(t)-shaped source vector.
    let mut x_prev = vec![0f32; m.n];
    let t1 = Instant::now();
    let mut checked = 0usize;
    for step in 0..STEPS {
        let phase = step as f32 * 0.05;
        let b: Vec<f32> = (0..m.n)
            .map(|i| 1.0 + 0.2 * ((i as f32 * 0.01 + phase).sin()) + 0.05 * x_prev[i])
            .collect();
        let resp = svc.solve(b.clone())?;
        if step % 50 == 0 {
            let want = solve_serial(&m, &b);
            for i in 0..m.n {
                let tol = 1e-3 * want[i].abs().max(1.0);
                assert!(
                    (resp.x[i] - want[i]).abs() <= tol,
                    "step {step} row {i}: {} vs {}",
                    resp.x[i],
                    want[i]
                );
            }
            checked += 1;
        }
        x_prev = resp.x;
    }
    let wall = t1.elapsed().as_secs_f64();
    let accel_total = svc.metrics.accel_seconds * STEPS as f64;
    println!(
        "{STEPS} steps in {:.2}s host wall ({:.2} ms/solve numeric path); \
         modeled accelerator time {:.2} ms total ({:.2} µs/solve); \
         {checked} steps verified against the serial reference",
        wall,
        wall * 1e3 / STEPS as f64,
        accel_total * 1e3,
        svc.metrics.accel_seconds * 1e6,
    );
    println!(
        "throughput: {:.1} solves/s host; accelerator-model {:.0} solves/s; \
         energy {:.2} µJ/solve",
        STEPS as f64 / wall,
        1.0 / svc.metrics.accel_seconds,
        svc.metrics.energy_j * 1e6,
    );
    // Phase 2: independent RHS stream submitted asynchronously — worker
    // rounds drain batches through the multi-RHS kernel (dispatch and
    // vals-staging amortized across 8 RHS per level).
    let t2 = Instant::now();
    let mut pend = Vec::with_capacity(STEPS);
    let mut bs = Vec::with_capacity(STEPS);
    for step in 0..STEPS {
        let b: Vec<f32> = (0..m.n)
            .map(|i| 1.0 + 0.3 * ((i + step) as f32 * 0.02).cos())
            .collect();
        pend.push(svc.submit(b.clone())?);
        bs.push(b);
    }
    for (step, rx) in pend.into_iter().enumerate() {
        let resp = rx.wait()?;
        if step % 100 == 0 {
            let want = solve_serial(&m, &bs[step]);
            for i in 0..m.n {
                assert!((resp.x[i] - want[i]).abs() <= 1e-3 * want[i].abs().max(1.0));
            }
        }
    }
    let wall2 = t2.elapsed().as_secs_f64();
    println!(
        "batched phase: {STEPS} independent RHS in {:.2}s ({:.1} solves/s, {:.2}x vs sequential)",
        wall2,
        STEPS as f64 / wall2,
        wall / wall2,
    );
    // Phase 3: the same stream through a pipelined `SolveSession` — a
    // bounded window of replies stays in flight so the worker queue never
    // runs dry between time steps, without buffering all 500 handles.
    let t3 = Instant::now();
    let mut session = svc.open_session(8)?;
    let mut bs3 = Vec::with_capacity(STEPS);
    let mut replies = Vec::with_capacity(STEPS);
    for step in 0..STEPS {
        let b: Vec<f32> = (0..m.n)
            .map(|i| 1.0 + 0.3 * ((i + step) as f32 * 0.02).cos())
            .collect();
        session.submit(b.clone())?;
        bs3.push(b);
        while let Some(reply) = session.try_next() {
            replies.push(reply?);
        }
    }
    for reply in session.drain() {
        replies.push(reply?);
    }
    assert_eq!(replies.len(), STEPS, "one reply per streamed time step");
    for (step, resp) in replies.iter().enumerate() {
        if step % 100 == 0 {
            let want = solve_serial(&m, &bs3[step]);
            for i in 0..m.n {
                assert!((resp.x[i] - want[i]).abs() <= 1e-3 * want[i].abs().max(1.0));
            }
        }
    }
    let wall3 = t3.elapsed().as_secs_f64();
    println!(
        "session phase: {STEPS} RHS through one depth-{} session in {:.2}s ({:.1} solves/s)",
        session.depth(),
        wall3,
        STEPS as f64 / wall3,
    );
    drop(session);
    let backend = svc.backend_name();
    svc.shutdown();
    println!("E2E OK: all layers composed (compiler -> sim verify -> {backend} numeric path)");
    Ok(())
}
