//! Fig. 6-style comparison: run the coarse, fine (DPU-v2 model) and medium
//! (this work) dataflows on the same SpTRSV DAGs and print cycles/GOPS.
//!
//! Run: `cargo run --release --example dataflow_compare`

use mgd_sptrsv::arch::ArchConfig;
use mgd_sptrsv::baselines::{coarse, fine};
use mgd_sptrsv::compiler::allocation::{allocate, AllocationPolicy};
use mgd_sptrsv::compiler::{schedule_only, CompilerConfig};
use mgd_sptrsv::graph::Dag;
use mgd_sptrsv::matrix::gen::{self, GenSeed};
use mgd_sptrsv::util::Table;

fn main() -> anyhow::Result<()> {
    let arch = ArchConfig::default();
    let cases = vec![
        ("chain (worst case)", gen::chain(500, GenSeed(1))),
        ("banded dw2048-like", gen::banded(2048, 24, 0.62, GenSeed(2))),
        ("circuit add20-like", gen::circuit(2395, 3, 0.8, GenSeed(3))),
        ("power-law rajat-like", gen::power_law(1041, 1.15, 160, GenSeed(4))),
        ("shallow c36-like", gen::shallow(7479, 0.55, GenSeed(5))),
    ];
    let mut table = Table::new(vec![
        "workload",
        "coarse cyc",
        "fine cyc@2x",
        "medium cyc",
        "coarse GOPS",
        "fine GOPS",
        "medium GOPS",
    ]);
    for (name, m) in &cases {
        let g = Dag::from_csr(m);
        let flops = m.binary_nodes() as u64;
        let alloc = allocate(&g, arch.num_cus(), AllocationPolicy::RoundRobin);
        let c = coarse::simulate(&g, &alloc)?;
        let fc = fine::FineConfig::default();
        let f = fine::simulate(&g, &fc)?;
        let s = schedule_only(m, &CompilerConfig::default())?;
        let medium_gops = flops as f64 / (s.stats.cycles as f64 / arch.clock_hz) / 1e9;
        table.row(vec![
            name.to_string(),
            c.cycles.to_string(),
            f.cycles.to_string(),
            s.stats.cycles.to_string(),
            format!("{:.2}", c.gops(arch.clock_hz, flops)),
            format!("{:.2}", f.gops(&fc)),
            format!("{medium_gops:.2}"),
        ]);
    }
    println!("{table}");
    println!(
        "(fine runs at 300 MHz with 1-op PEs; coarse/medium at 150 MHz with \
         2-op PEs — the paper's fairness rule)"
    );
    Ok(())
}
