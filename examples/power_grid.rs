//! Power-network scaling study (ACTIVSg-like): sweep grid sizes, compile,
//! simulate, and report throughput/utilization/energy — the paper's
//! scalability angle on Fig. 12.
//!
//! Run: `cargo run --release --example power_grid`

use mgd_sptrsv::arch::ArchConfig;
use mgd_sptrsv::compiler::{compile, CompilerConfig};
use mgd_sptrsv::matrix::gen::{self, GenSeed};
use mgd_sptrsv::sim::{Accelerator, EnergyModel};
use mgd_sptrsv::util::Table;

fn main() -> anyhow::Result<()> {
    let arch = ArchConfig::default();
    let model = EnergyModel::paper_28nm();
    let mut table = Table::new(vec![
        "grid",
        "n",
        "nnz",
        "cycles",
        "GOPS",
        "util %",
        "power mW",
        "GOPS/W",
        "compile ms",
    ]);
    for side in [16usize, 32, 48, 64, 96, 128] {
        let m = gen::grid2d(side, side, true, GenSeed(7));
        let cfg = CompilerConfig {
            arch,
            ..CompilerConfig::default()
        };
        let prog = compile(&m, &cfg)?;
        let mut acc = Accelerator::new(arch);
        let run = acc.run(&prog, &vec![1.0f32; m.n])?;
        run.stats.verify_against(&prog.predicted)?;
        let gops = run.gops(&arch, prog.flops());
        let e = model.estimate(&run.stats, &arch);
        table.row(vec![
            format!("{side}x{side}"),
            m.n.to_string(),
            m.nnz().to_string(),
            run.stats.cycles.to_string(),
            format!("{gops:.2}"),
            format!("{:.1}", 100.0 * run.stats.utilization(arch.num_cus())),
            format!("{:.1}", e.avg_power_w * 1e3),
            format!("{:.1}", e.gops_per_watt(gops)),
            format!("{:.1}", prog.compile.compile_seconds * 1e3),
        ]);
    }
    println!("{table}");
    Ok(())
}
