#!/usr/bin/env python3
"""Docs lint: keep the operator documentation honest.

Usage:
    python3 ci/lint_docs.py             # lint the tree (exit 1 on violations)
    python3 ci/lint_docs.py --selftest  # run against ci/fixtures/lint_docs/

Two rules:

A. Links. Every relative markdown link target in the repo's *.md files
   must resolve to an existing file or directory (fragments are stripped
   first; absolute http(s)/mailto targets and pure #anchors are skipped).
   Vendored trees and the lint fixtures themselves are excluded.

B. Flags. Every standalone backticked `--flag` token in the operator
   docs (README.md and docs/**/*.md) must exist in the CLI source
   (rust/src/cli.rs) — so a renamed or removed serve/bench flag cannot
   linger in the knobs tables. Backticked snippets that are whole
   commands (spaces before the flag) are not matched; a short allowlist
   covers cargo/python flags the docs legitimately mention.

The lint is intentionally line-based and dependency-free: it runs on the
stock python3 of the CI image, before any cargo build.
"""

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "ci" / "fixtures" / "lint_docs"
CLI = REPO / "rust" / "src" / "cli.rs"

# Directories never scanned (vendored code, VCS internals, build output,
# and the deliberately-broken lint fixtures).
EXCLUDE_PARTS = {".git", "vendor", "target", "fixtures", ".claude"}

# Inline markdown link: [text](target). Images share the syntax.
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

# A backticked token that *is* a flag: `--name` or `--name VALUE`. A
# flag buried inside a longer backticked command (preceded by a space)
# deliberately does not match.
BACKTICKED_FLAG = re.compile(r"`(--[a-z][a-z0-9-]*)(?: [^`]*)?`")

# Flag-shaped tokens in the CLI source (usage strings, flag_value calls,
# tests) — the ground truth rule B checks against.
CLI_FLAG = re.compile(r"--[a-z][a-z0-9-]*")

# Cargo/python flags the docs legitimately mention outside the CLI.
EXTERNAL_FLAGS = {
    "--all-targets",
    "--bench",
    "--bin",
    "--check",
    "--example",
    "--features",
    "--help",
    "--lib",
    "--no-deps",
    "--release",
    "--selftest",
    "--workspace",
}


def rel(path):
    return path.relative_to(REPO).as_posix()


def cli_flags():
    return set(CLI_FLAG.findall(CLI.read_text(encoding="utf-8")))


def check_flags(path, known, violations):
    """Rule B on one operator-docs file."""
    relpath = rel(path) if path.is_relative_to(REPO) else path.name
    for i, line in enumerate(path.read_text(encoding="utf-8").splitlines()):
        for m in BACKTICKED_FLAG.finditer(line):
            flag = m.group(1)
            if flag not in known and flag not in EXTERNAL_FLAGS:
                violations.append(
                    f"{relpath}:{i + 1}: [flag] documented flag {flag} does "
                    f"not exist in rust/src/cli.rs"
                )


def check_links(path, violations):
    """Rule A on one markdown file."""
    relpath = rel(path) if path.is_relative_to(REPO) else path.name
    for i, line in enumerate(path.read_text(encoding="utf-8").splitlines()):
        for m in LINK.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            plain = target.split("#", 1)[0]
            if not plain:
                continue
            resolved = (path.parent / plain).resolve()
            if not resolved.exists():
                violations.append(
                    f"{relpath}:{i + 1}: [link] relative link target "
                    f"{target!r} does not resolve"
                )


def operator_docs():
    docs = [REPO / "README.md"]
    docs_dir = REPO / "docs"
    if docs_dir.is_dir():
        docs.extend(sorted(docs_dir.rglob("*.md")))
    return [d for d in docs if d.is_file()]


def lint_tree():
    violations = []
    known = cli_flags()
    for path in sorted(REPO.rglob("*.md")):
        if EXCLUDE_PARTS.intersection(path.relative_to(REPO).parts):
            continue
        check_links(path, violations)
    for path in operator_docs():
        check_flags(path, known, violations)
    return violations


def selftest():
    """The fixture contract: fail.md trips every rule, pass.md none."""
    known = cli_flags()
    failures = []
    check_links(FIXTURES / "fail.md", failures)
    check_flags(FIXTURES / "fail.md", known, failures)
    tags = {v.split("[", 1)[1].split("]", 1)[0] for v in failures}
    want = {"link", "flag"}
    if tags != want:
        print(f"selftest FAILED: fail.md tripped {sorted(tags)}, want {sorted(want)}")
        for v in failures:
            print(" ", v)
        return 1
    passes = []
    check_links(FIXTURES / "pass.md", passes)
    check_flags(FIXTURES / "pass.md", known, passes)
    if passes:
        print("selftest FAILED: pass.md tripped rules:")
        for v in passes:
            print(" ", v)
        return 1
    print(f"selftest OK: fail.md tripped {sorted(want)}; pass.md is clean")
    return 0


def main():
    if "--selftest" in sys.argv[1:]:
        return selftest()
    violations = lint_tree()
    if violations:
        print(f"lint_docs: {len(violations)} violation(s)")
        for v in violations:
            print(" ", v)
        return 1
    print("lint_docs: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
