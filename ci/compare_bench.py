#!/usr/bin/env python3
"""Bench-regression gate: compare a BENCH_*.json artifact against its
committed baseline.

Usage:
    python3 ci/compare_bench.py <baseline.json> <bench.json>

The baseline file pins the headline ratio(s) of one experiment:

    {
      "experiment": "schedulers",
      "metrics": {
        "deep_geomean_speedup": {"baseline": 1.6, "tolerance": 0.25}
      }
    }

For each metric, the gate reads the same-named top-level key from the
bench JSON and FAILS (exit 1) when

    value < baseline * (1 - tolerance)

i.e. a >25% regression of the pinned ratio (per-metric tolerance
overridable). The check is one-sided on purpose: these are
speedup/throughput ratios measured on shared CI runners, where the
*upside* is noisy but a collapse (the optimized path losing to its
baseline) is exactly the regression the gate exists to catch.
Improvements print a note suggesting the baseline be re-pinned.

Baselines live in ci/bench_baselines/ and should be re-pinned from the
uploaded workflow artifacts whenever the runner class or the headline
workloads change.

No third-party dependencies; runs on the stock python3 of the CI image.
"""

import json
import sys

DEFAULT_TOLERANCE = 0.25


def fail(msg):
    print(f"bench-regression: ERROR: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    if len(sys.argv) != 3:
        fail(f"usage: {sys.argv[0]} <baseline.json> <bench.json>")
    baseline_path, bench_path = sys.argv[1], sys.argv[2]
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"cannot read baseline {baseline_path}: {e}")
    try:
        with open(bench_path) as f:
            bench = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"cannot read bench artifact {bench_path}: {e}")

    experiment = baseline.get("experiment")
    if not experiment:
        fail(f"{baseline_path} has no 'experiment' field")
    if bench.get("experiment") != experiment:
        fail(
            f"experiment mismatch: baseline is {experiment!r}, "
            f"artifact is {bench.get('experiment')!r}"
        )
    metrics = baseline.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        fail(f"{baseline_path} pins no metrics")

    regressions = []
    for name, spec in sorted(metrics.items()):
        if name not in bench:
            fail(f"artifact {bench_path} is missing pinned metric {name!r}")
        value = bench[name]
        if not isinstance(value, (int, float)):
            fail(f"metric {name!r} is not numeric in {bench_path}: {value!r}")
        pinned = spec.get("baseline")
        if not isinstance(pinned, (int, float)) or pinned <= 0:
            fail(f"baseline for {name!r} must be a positive number, got {pinned!r}")
        tolerance = spec.get("tolerance", DEFAULT_TOLERANCE)
        floor = pinned * (1.0 - tolerance)
        status = "OK"
        if value < floor:
            status = "REGRESSION"
            regressions.append(name)
        elif value > pinned * (1.0 + tolerance):
            status = "improved (consider re-pinning the baseline)"
        print(
            f"bench-regression[{experiment}] {name}: value={value:.4f} "
            f"baseline={pinned:.4f} floor={floor:.4f} ({tolerance:.0%} tol) -> {status}"
        )

    if regressions:
        fail(
            f"{experiment}: {len(regressions)} metric(s) regressed >"
            f" tolerance: {', '.join(regressions)}"
        )
    print(f"bench-regression[{experiment}]: all pinned metrics within tolerance")


if __name__ == "__main__":
    main()
