//! Clean fixture for ci/lint_sync.py --selftest: exercises every rule's
//! allowed form and must produce zero violations. Never compiled.

// Rule A: data-plumbing re-exports may come from std; instrumented
// primitives come through the facade.
use crate::runtime::sync::atomic::{AtomicU64, Ordering};
use crate::runtime::sync::{Condvar, Mutex};
use std::sync::{mpsc, Arc, OnceLock};

struct Counter(AtomicU64);

impl Counter {
    fn bump(&self) -> u64 {
        // relaxed: monotonic telemetry counter, no data published under it.
        self.0.fetch_add(1, Ordering::Relaxed)
    }

    fn peek(&self) -> u64 {
        // SAFETY: the counter is plain memory and u64 loads are valid
        // for any bit pattern; this fixture never runs anyway.
        unsafe { *(&self.0 as *const _ as *const u64) }
    }
}
