//! Rule-D violation fixture for ci/lint_sync.py --selftest: unchecked
//! indexing inside runtime/kir/ whose SAFETY comment does not name the
//! verifier, so the bounds obligation is undischarged. Must trip exactly
//! the [kir] rule (the SAFETY marker keeps rule C satisfied). Never
//! compiled — lint input only.

fn gather(scratch: &[f32], src: u32) -> f32 {
    // SAFETY: trust me, the index is fine.
    unsafe { *scratch.get_unchecked(src as usize) }
}
