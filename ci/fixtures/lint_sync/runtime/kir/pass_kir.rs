//! Clean rule-D fixture for ci/lint_sync.py --selftest: unchecked
//! indexing inside runtime/kir/ with a SAFETY comment naming the
//! verifier lemma that discharges it. Never compiled — lint input only.

fn gather(scratch: &[f32], src: u32) -> f32 {
    // SAFETY: kir::verify lemma mac-window proves src < scratch.len()
    // for every program the interpreter is allowed to execute.
    unsafe { *scratch.get_unchecked(src as usize) }
}
