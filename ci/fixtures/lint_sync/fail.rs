//! Violation fixture for ci/lint_sync.py --selftest: every rule must
//! trip at least once in this file. Never compiled — lint input only.

// Rule A: instrumented primitive imported straight from std::sync.
use std::sync::{Arc, Mutex};

struct Counter(std::sync::atomic::AtomicU64);

impl Counter {
    fn bump(&self) -> u64 {
        // Rule B: no justification marker anywhere near this ordering.
        self.0.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    }

    fn peek(&self) -> u64 {
        // Rule C: no safety comment anywhere near this block.
        unsafe { *(&self.0 as *const _ as *const u64) }
    }

    fn first(&self, xs: &[u64]) -> u64 {
        // Rule D: unchecked indexing outside runtime/kir/ — the SAFETY
        // comment satisfies rule C but not the location requirement.
        // SAFETY: the caller promises xs is non-empty.
        unsafe { *xs.get_unchecked(0) }
    }
}
