#!/usr/bin/env python3
"""Sync-facade lint: static concurrency-hygiene rules for rust/src.

Usage:
    python3 ci/lint_sync.py             # lint the tree (exit 1 on violations)
    python3 ci/lint_sync.py --selftest  # run against ci/fixtures/lint_sync/

Four rules, all enforced on rust/src/**/*.rs (tests under rust/tests/
and benches are exempt — they model *external* users of the library):

A. Facade discipline. The instrumented primitives must flow through
   `runtime::sync` so the in-tree model checker sees every lock, wait and
   notify. Importing Mutex/Condvar/RwLock/Barrier or the `atomic` module
   from `std::sync` is an error anywhere except the facade itself
   (rust/src/runtime/sync.rs). Plain data-plumbing re-exports (Arc, Weak,
   mpsc, OnceLock, LockResult, PoisonError, TryLockError) may come from
   either path.

B. Relaxed justification. `Ordering::Relaxed` is free in the whitelisted
   telemetry modules (coordinator/metrics.rs, coordinator/registry.rs).
   Everywhere else each use must carry a `relaxed:` justification marker
   in a comment on the same line or within the 5 preceding lines —
   forcing the author to say why no happens-before edge is needed (the
   protocol arguments live in rust/src/runtime/atomics.md). `#[cfg(test)]`
   modules are exempt.

C. Safety comments. Every line containing an `unsafe` token must have a
   `SAFETY:` comment on the same line or within the 5 preceding lines.

D. Verifier-gated unchecked indexing. `get_unchecked`/`get_unchecked_mut`
   is the kernel-IR interpreter's privilege: it may appear only under
   rust/src/runtime/kir/, and each use must sit within 5 lines of a
   `SAFETY:` comment whose window also names the verifier (`verify`) —
   the abstract-interpretation lemma that discharges the bounds
   obligation. Anywhere else, unchecked indexing is an error outright.

The lint is intentionally line-based and dependency-free: it runs on the
stock python3 of the CI image, before any cargo build.
"""

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "rust" / "src"
FIXTURES = REPO / "ci" / "fixtures" / "lint_sync"

# The facade module itself: the only place std's instrumented primitives
# may be named.
FACADE = "runtime/sync.rs"

# Modules whose Relaxed telemetry counters are documented wholesale in
# runtime/atomics.md; per-site markers would be noise there.
RELAXED_WHITELIST = {
    "coordinator/metrics.rs",
    "coordinator/registry.rs",
}

# std::sync names that must come from the facade instead.
INSTRUMENTED = r"(?:Mutex|Condvar|RwLock|Barrier|atomic)"

# `use std::sync::X` / `use std::sync::{..}` importing an instrumented
# primitive, in either position (direct path or inside a brace list).
DIRECT_IMPORT = re.compile(
    r"use\s+std\s*::\s*sync\s*::\s*" + INSTRUMENTED + r"\b"
)
BRACE_IMPORT = re.compile(r"use\s+std\s*::\s*sync\s*::\s*\{([^}]*)\}")
BRACE_NAME = re.compile(r"^" + INSTRUMENTED + r"$")

RELAXED = re.compile(r"Ordering\s*::\s*Relaxed|\bRelaxed\b\s*\)")
RELAXED_MARKER = "relaxed:"
MARKER_WINDOW = 5

UNSAFE = re.compile(r"\bunsafe\b")
SAFETY_MARKER = "SAFETY:"
CFG_TEST = re.compile(r"#\s*\[\s*cfg\s*\(\s*test\s*\)\s*\]")

# Unchecked slice indexing: only the verifier-gated kernel-IR interpreter
# may use it (rule D).
UNCHECKED = re.compile(r"\bget_unchecked(?:_mut)?\b")
KIR_DIR = "runtime/kir/"


def rel(path):
    return path.relative_to(REPO).as_posix()


def test_module_start(lines):
    """Index of the `#[cfg(test)]` attribute opening the trailing test
    module, or len(lines) if the file has none. Everything from there on
    is exempt from rule B (tests assert on counters; they are not part of
    the cross-thread protocol)."""
    for i, line in enumerate(lines):
        if CFG_TEST.search(line) and i + 1 < len(lines) and "mod " in lines[i + 1]:
            return i
    return len(lines)


def lint_file(path, violations):
    relpath = rel(path)
    text = path.read_text(encoding="utf-8")
    lines = text.splitlines()
    in_facade = relpath.endswith(FACADE)
    tests_at = test_module_start(lines)

    for i, line in enumerate(lines):
        code = line.split("//")[0]

        # Rule A: no std::sync imports of instrumented primitives
        # outside the facade.
        if not in_facade:
            hit = DIRECT_IMPORT.search(code)
            if not hit:
                brace = BRACE_IMPORT.search(code)
                if brace:
                    names = [n.strip() for n in brace.group(1).split(",")]
                    hit = any(BRACE_NAME.match(n) for n in names if n)
            if hit:
                violations.append(
                    f"{relpath}:{i + 1}: [facade] import the instrumented "
                    f"primitive from crate::runtime::sync, not std::sync: "
                    f"{line.strip()}"
                )

        # Rule B: Relaxed needs a nearby `relaxed:` marker.
        if (
            relpath[len("rust/src/") :] not in RELAXED_WHITELIST
            and i < tests_at
            and RELAXED.search(code)
        ):
            window = lines[max(0, i - MARKER_WINDOW) : i + 1]
            if not any(RELAXED_MARKER in w for w in window):
                violations.append(
                    f"{relpath}:{i + 1}: [relaxed] Ordering::Relaxed without a "
                    f"`relaxed:` justification marker within {MARKER_WINDOW} "
                    f"lines: {line.strip()}"
                )

        # Rule C: unsafe needs a nearby SAFETY: comment. Scan the full
        # line (the marker usually lives in a comment).
        if UNSAFE.search(code):
            window = lines[max(0, i - MARKER_WINDOW) : i + 1]
            if not any(SAFETY_MARKER in w for w in window):
                violations.append(
                    f"{relpath}:{i + 1}: [safety] unsafe without a `SAFETY:` "
                    f"comment within {MARKER_WINDOW} lines: {line.strip()}"
                )

        # Rule D: unchecked indexing only inside the verifier-gated
        # kernel-IR interpreter, and there only under a SAFETY window
        # that cites the verifier.
        if UNCHECKED.search(code):
            if KIR_DIR not in relpath:
                violations.append(
                    f"{relpath}:{i + 1}: [kir] unchecked indexing outside "
                    f"{KIR_DIR} — only the verifier-gated kernel-IR "
                    f"interpreter may skip bounds checks: {line.strip()}"
                )
            else:
                window = lines[max(0, i - MARKER_WINDOW) : i + 1]
                if not (
                    any(SAFETY_MARKER in w for w in window)
                    and any("verify" in w for w in window)
                ):
                    violations.append(
                        f"{relpath}:{i + 1}: [kir] unchecked indexing without "
                        f"a `SAFETY:` comment naming the verifier lemma "
                        f"within {MARKER_WINDOW} lines: {line.strip()}"
                    )


def lint_tree(root):
    violations = []
    for path in sorted(root.rglob("*.rs")):
        lint_file(path, violations)
    return violations


def selftest():
    """The fixture contract: fail.rs trips every rule, pass.rs none;
    the runtime/kir/ fixtures pin rule D's location-sensitive halves
    (fail_kir.rs trips exactly [kir], pass_kir.rs is clean)."""
    fail_path = FIXTURES / "fail.rs"
    pass_path = FIXTURES / "pass.rs"
    failures = []
    lint_file(fail_path, failures)
    tags = {v.split("[", 1)[1].split("]", 1)[0] for v in failures}
    want = {"facade", "relaxed", "safety", "kir"}
    if tags != want:
        print(f"selftest FAILED: fail.rs tripped {sorted(tags)}, want {sorted(want)}")
        for v in failures:
            print(" ", v)
        return 1
    kir_failures = []
    lint_file(FIXTURES / "runtime" / "kir" / "fail_kir.rs", kir_failures)
    kir_tags = {v.split("[", 1)[1].split("]", 1)[0] for v in kir_failures}
    if kir_tags != {"kir"}:
        print(f"selftest FAILED: fail_kir.rs tripped {sorted(kir_tags)}, want ['kir']")
        for v in kir_failures:
            print(" ", v)
        return 1
    passes = []
    lint_file(pass_path, passes)
    lint_file(FIXTURES / "runtime" / "kir" / "pass_kir.rs", passes)
    if passes:
        print("selftest FAILED: pass fixtures tripped rules:")
        for v in passes:
            print(" ", v)
        return 1
    print(f"selftest OK: fail fixtures tripped {sorted(want)}; pass fixtures are clean")
    return 0


def main():
    if "--selftest" in sys.argv[1:]:
        return selftest()
    violations = lint_tree(SRC)
    if violations:
        print(f"lint_sync: {len(violations)} violation(s)")
        for v in violations:
            print(" ", v)
        return 1
    print("lint_sync: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
